//! The shard wire protocol: a versioned, schema-tagged, serializable
//! form of the cluster/service boundary.
//!
//! Everything a [`super::transport::ShardTransport`] moves between the
//! front router and a shard is expressed here as two explicit message
//! enums — [`ShardMsg`] (router → shard: hello/submit/cancel/stats/
//! drain; a resubmission is a `Submit` carrying a `resume` snapshot) and
//! [`ShardReply`] (shard → router: ready/response/stats/drained/error)
//! — encoded as [`Json`] documents framed with a 4-byte big-endian
//! length prefix.  The same `MatchService` semantics run on both sides;
//! only the transport differs.
//!
//! Encoding rules, chosen so a warm-start [`SwarmSnapshot`] that
//! crosses a process boundary resumes **bit-identically**:
//!
//! * every f32 travels as its u32 bit pattern (JSON numbers are f64 —
//!   a u32 is exact, while a pretty-printed float would corrupt
//!   ±inf/NaN and is one rounding bug away from breaking resume);
//! * 64-bit words that may exceed 2^53 (request ids, seeds, budgets,
//!   RNG state) travel as 16-digit hex strings;
//! * graphs travel sparse: CSR edge lists and per-row mask candidate
//!   columns — never a dense matrix;
//! * every frame carries the [`WIRE_SCHEMA`] tag and a `"t"` type tag;
//!   a schema mismatch, an unknown type, an oversized frame, or a
//!   truncated frame is a loud decode error, never a guess.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::coordinator::{
    ControllerStats, MatchPath, MatchProblem, MatchResponse, RequestId, RouterStats,
    ServiceConfig, ServiceStats,
};
use crate::graph::Csr;
use crate::matcher::{BitMask, Mapping, PsoConfig, SwarmSnapshot};
use crate::obs::trace::{SpanKind, TraceCtx, TraceEvent};
use crate::scheduler::Priority;
use crate::util::json::{
    as_index, decode_opt_indices, encode_opt_indices, f32_bits, get_bool, get_dim, get_f32_bits,
    get_hex_u64, get_str, get_u64, get_usize, hex_u64, Json,
};

/// Protocol version tag carried by every frame.  Bump on any layout
/// change: a mixed-version router/worker pair must fail the handshake,
/// not mis-decode swarm state.  v4 added the observability plane:
/// `submit` carries an optional trace context and `response` piggybacks
/// the worker-side span timeline.
pub const WIRE_SCHEMA: &str = "immsched.shard-wire/v4";

/// Hard ceiling on one frame's payload (64 MiB).  The largest real
/// payload is a `huge`-class problem + snapshot (a few MiB of JSON); a
/// length prefix beyond this is a corrupt or hostile stream and is
/// rejected before any allocation.
pub const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

// ---------------------------------------------------------------------------
// message enums
// ---------------------------------------------------------------------------

/// Router → shard.
///
/// `Submit` dwarfs the control variants by design — it carries the
/// whole problem + optional snapshot, and boxing it would only move
/// the indirection into every transport hot path.
#[allow(clippy::large_enum_variant)]
#[derive(Clone, Debug)]
pub enum ShardMsg {
    /// Handshake: must be the first frame on a connection.  Carries the
    /// shard's full configuration so a worker process needs no
    /// out-of-band config channel.
    Hello { service: ServiceConfig, pso: PsoConfig },
    /// Submit (or, with `resume`, resubmit) one request.  `timeout` is
    /// relative seconds from receipt — absolute deadlines never cross
    /// the boundary, because the two sides do not share a clock.
    /// `trace`, when present, asks the worker to record spans for this
    /// request and ship them back on the response (v4).
    Submit {
        id: RequestId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
        resume: Option<SwarmSnapshot>,
        trace: Option<TraceCtx>,
    },
    /// Cancel the identified request at its next epoch barrier.
    Cancel { id: RequestId },
    /// Request a [`ShardReply::Stats`] load report.
    Stats,
    /// Finish everything in flight, answer [`ShardReply::Drained`],
    /// then exit.
    Drain,
}

/// Shard → router.
#[allow(clippy::large_enum_variant)]
#[derive(Clone, Debug)]
pub enum ShardReply {
    /// Handshake acknowledgement (echoes the protocol schema).
    Ready { schema: String },
    /// A request's final answer.  Out-of-order by design: the shard's
    /// admission queue reorders by priority/deadline.  Since v3 every
    /// response piggybacks the shard's post-completion [`ShardStatus`]
    /// so the router's TTL status cache refreshes for free on each
    /// reply instead of only via heartbeat probes (`None` keeps older
    /// senders representable in memory, never on the wire).  Since v4
    /// the worker's span timeline for the request rides along (empty
    /// unless the submit carried a trace context), so a multi-host
    /// request stitches into one timeline on the router.
    Response { response: MatchResponse, status: Option<ShardStatus>, spans: Vec<TraceEvent> },
    /// Non-blocking load report — the routing policies' input.
    Stats(ShardStatus),
    /// Drain complete; `answered` counts responses sent over this
    /// connection's lifetime.
    Drained { answered: u64 },
    /// A handshake- or protocol-level failure (bad hello, duplicate
    /// hello).  Per-request failures are answered as shed
    /// [`ShardReply::Response`]s instead — an error carries no request
    /// id, so it could never release the right waiter.  Undecodable
    /// *frames* are connection-fatal on both sides: out-of-sync framing
    /// poisons everything after it.
    Error { context: String },
}

/// One shard's routing-relevant load, as reported by its transport —
/// the only view `RoutePolicy` implementations see, so in-process and
/// out-of-process shards are indistinguishable to routing.
#[derive(Clone, Debug, Default)]
pub struct ShardStatus {
    /// Queued requests not yet popped for service.
    pub queue_depth: usize,
    /// Priority of the episode currently on the controller, if any.
    pub in_flight: Option<Priority>,
    /// Request id of that episode — the shard's in-flight inventory.
    /// Fleet supervision reads it so a dead shard's victim is known
    /// for replay without waiting for its waiter to notice.
    pub in_flight_id: Option<RequestId>,
    /// Full service telemetry (controller + admission router).
    pub stats: ServiceStats,
}

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

/// Write one length-prefixed frame and flush (the peer blocks on it).
pub fn write_frame<W: Write>(w: &mut W, doc: &Json) -> Result<()> {
    let payload = doc.render();
    let bytes = payload.as_bytes();
    anyhow::ensure!(bytes.len() <= MAX_FRAME_BYTES, "frame of {} bytes too large", bytes.len());
    let len = u32::try_from(bytes.len()).context("frame length exceeds u32")?;
    w.write_all(&len.to_be_bytes()).context("writing frame length")?;
    w.write_all(bytes).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one frame.  `Ok(None)` on clean EOF *between* frames; EOF
/// mid-length or mid-payload is a truncation error, as is a length
/// prefix beyond [`MAX_FRAME_BYTES`] or an unparseable payload.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Json>> {
    let mut len = [0u8; 4];
    match r.read(&mut len)? {
        0 => return Ok(None),
        mut got => {
            while got < 4 {
                // lint:allow(no-panic-transport): got < 4 is the loop guard, so the
                // len[got..] slice of the 4-byte prefix buffer cannot go out of bounds
                let more = r.read(&mut len[got..])?;
                if more == 0 {
                    bail!("truncated frame: EOF inside the length prefix ({got}/4 bytes)");
                }
                got += more;
            }
        }
    }
    let len = usize::try_from(u32::from_be_bytes(len))
        .context("frame length exceeds this platform's address space")?;
    anyhow::ensure!(
        len <= MAX_FRAME_BYTES,
        "frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap"
    );
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .with_context(|| format!("truncated frame: EOF inside a {len}-byte payload"))?;
    let text = std::str::from_utf8(&payload).context("frame payload is not UTF-8")?;
    Ok(Some(Json::parse(text).context("frame payload is not valid JSON")?))
}

// ---------------------------------------------------------------------------
// field helpers (bit-exact primitives live in util::json — shared with
// SwarmSnapshot serde so the two codecs cannot drift)
// ---------------------------------------------------------------------------

fn get_f64(v: &Json, key: &str) -> Result<f64> {
    // a non-finite f64 renders as null (see util::json); decode it back
    // to NaN rather than failing — it is telemetry, not control state
    match v.get(key) {
        Some(Json::Null) => Ok(f64::NAN),
        Some(x) => x.as_f64().with_context(|| format!("field {key:?} is not a number")),
        None => bail!("missing numeric field {key:?}"),
    }
}

fn encode_priority(p: Priority) -> Json {
    Json::from(p.name())
}

fn decode_priority(v: &Json, key: &str) -> Result<Priority> {
    let name = get_str(v, key)?;
    Priority::from_name(name).with_context(|| format!("unknown priority {name:?}"))
}

// ---------------------------------------------------------------------------
// graph / problem codec
// ---------------------------------------------------------------------------

/// CSR adjacency as `{nodes, edges: [u0, v0, u1, v1, ...]}` (row-major
/// edge order, the form [`Csr::edges`] emits).
pub fn encode_csr(csr: &Csr) -> Json {
    let mut flat = Vec::with_capacity(csr.edge_count() * 2);
    for (u, v) in csr.edges() {
        flat.push(Json::Num(f64::from(u)));
        flat.push(Json::Num(f64::from(v)));
    }
    Json::obj(vec![("nodes", Json::from(csr.nodes())), ("edges", Json::Arr(flat))])
}

/// Inverse of [`encode_csr`].
pub fn decode_csr(v: &Json) -> Result<Csr> {
    let nodes = get_dim(v, "nodes")?;
    let flat = v.get("edges").and_then(Json::as_array).context("csr missing edges")?;
    anyhow::ensure!(flat.len() % 2 == 0, "csr edge list has an odd element count");
    let endpoint = |x: &Json| -> Result<u32> {
        let x = as_index(x).context("csr edge endpoint")?;
        u32::try_from(x).context("csr edge endpoint out of range")
    };
    let mut pairs = Vec::with_capacity(flat.len() / 2);
    for uv in flat.chunks_exact(2) {
        let [u, v] = uv else { bail!("csr edge chunk is not a pair") };
        pairs.push((endpoint(u)?, endpoint(v)?));
    }
    Csr::from_edge_pairs(nodes, &pairs)
}

/// Packed compatibility mask as `{rows, cols, set: [[cols...], ...]}` —
/// one candidate-column list per query row.
pub fn encode_mask(mask: &BitMask) -> Json {
    let rows: Vec<Json> = (0..mask.rows())
        .map(|i| {
            Json::Arr((0..mask.cols()).filter(|&j| mask.get(i, j)).map(Json::from).collect())
        })
        .collect();
    Json::obj(vec![
        ("rows", Json::from(mask.rows())),
        ("cols", Json::from(mask.cols())),
        ("set", Json::Arr(rows)),
    ])
}

/// Largest mask the decoder will allocate (cells = rows × cols); the
/// per-dimension cap alone would still let a 60-byte frame demand a
/// 2^40-cell bitset.
const MAX_MASK_CELLS: usize = 1 << 28;

/// Inverse of [`encode_mask`].
pub fn decode_mask(v: &Json) -> Result<BitMask> {
    let rows = get_dim(v, "rows")?;
    let cols = get_dim(v, "cols")?;
    let cells = rows.checked_mul(cols).context("mask shape overflows")?;
    anyhow::ensure!(
        cells <= MAX_MASK_CELLS,
        "mask of {cells} cells exceeds the {MAX_MASK_CELLS}-cell cap"
    );
    let set = v.get("set").and_then(Json::as_array).context("mask missing set rows")?;
    anyhow::ensure!(set.len() == rows, "mask has {} set rows, expected {rows}", set.len());
    let mut mask = BitMask::zeros(rows, cols);
    for (i, row) in set.iter().enumerate() {
        for j in row.as_array().context("mask row must be an array")? {
            let j = as_index(j).context("mask column")?;
            anyhow::ensure!(j < cols, "mask column {j} outside {cols} columns");
            mask.set(i, j);
        }
    }
    Ok(mask)
}

/// One owned matching instance (`query`/`target` CSR + packed mask).
pub fn encode_problem(p: &MatchProblem) -> Json {
    Json::obj(vec![
        ("query", encode_csr(&p.query)),
        ("target", encode_csr(&p.target)),
        ("mask", encode_mask(&p.mask)),
    ])
}

/// Inverse of [`encode_problem`]; the mask shape must match the graphs.
pub fn decode_problem(v: &Json) -> Result<MatchProblem> {
    let query = decode_csr(v.get("query").context("problem missing query")?)?;
    let target = decode_csr(v.get("target").context("problem missing target")?)?;
    let mask = decode_mask(v.get("mask").context("problem missing mask")?)?;
    anyhow::ensure!(
        mask.rows() == query.nodes() && mask.cols() == target.nodes(),
        "mask {}x{} does not match query {} / target {} vertices",
        mask.rows(),
        mask.cols(),
        query.nodes(),
        target.nodes()
    );
    Ok(MatchProblem { query, target, mask })
}

// ---------------------------------------------------------------------------
// config / stats / response codec
// ---------------------------------------------------------------------------

fn encode_service_config(cfg: &ServiceConfig) -> Json {
    Json::obj(vec![
        ("queue_depth", Json::from(cfg.queue_depth)),
        ("epoch_quota", cfg.epoch_quota.map_or(Json::Null, Json::from)),
    ])
}

fn decode_service_config(v: &Json) -> Result<ServiceConfig> {
    Ok(ServiceConfig {
        queue_depth: get_usize(v, "queue_depth")?,
        epoch_quota: match v.get("epoch_quota") {
            None | Some(Json::Null) => None,
            Some(_) => Some(get_usize(v, "epoch_quota")?),
        },
    })
}

fn encode_pso_config(cfg: &PsoConfig) -> Json {
    Json::obj(vec![
        ("particles", Json::from(cfg.particles)),
        ("epochs", Json::from(cfg.epochs)),
        ("steps", Json::from(cfg.steps)),
        ("w", f32_bits(cfg.w)),
        ("c1", f32_bits(cfg.c1)),
        ("c2", f32_bits(cfg.c2)),
        ("c3", f32_bits(cfg.c3)),
        ("elite", Json::from(cfg.elite)),
        ("relaxed", Json::from(cfg.relaxed)),
        ("early_exit", Json::from(cfg.early_exit)),
        ("repair_budget", hex_u64(cfg.repair_budget)),
        ("threads", Json::from(cfg.threads)),
        ("seed", hex_u64(cfg.seed)),
    ])
}

fn decode_pso_config(v: &Json) -> Result<PsoConfig> {
    Ok(PsoConfig {
        particles: get_usize(v, "particles")?,
        epochs: get_usize(v, "epochs")?,
        steps: get_usize(v, "steps")?,
        w: get_f32_bits(v, "w")?,
        c1: get_f32_bits(v, "c1")?,
        c2: get_f32_bits(v, "c2")?,
        c3: get_f32_bits(v, "c3")?,
        elite: get_usize(v, "elite")?,
        relaxed: get_bool(v, "relaxed")?,
        early_exit: get_bool(v, "early_exit")?,
        repair_budget: get_hex_u64(v, "repair_budget")?,
        threads: get_usize(v, "threads")?,
        seed: get_hex_u64(v, "seed")?,
    })
}

fn encode_service_stats(s: &ServiceStats) -> Json {
    let c = s.controller;
    let r = s.router;
    Json::obj(vec![
        (
            "controller",
            Json::obj(vec![
                ("requests", Json::from(c.requests)),
                ("matched", Json::from(c.matched)),
                ("fallbacks", Json::from(c.fallbacks)),
                ("rejected", Json::from(c.rejected)),
                ("cancelled", Json::from(c.cancelled)),
                ("resumed", Json::from(c.resumed)),
                ("epochs_total", Json::from(c.epochs_total)),
            ]),
        ),
        (
            "router",
            Json::obj(vec![
                ("admitted", Json::from(r.admitted)),
                ("shed_expired", Json::from(r.shed_expired)),
                ("shed_capacity", Json::from(r.shed_capacity)),
                ("served", Json::from(r.served)),
                ("depth", Json::from(r.depth)),
            ]),
        ),
    ])
}

fn decode_service_stats(v: &Json) -> Result<ServiceStats> {
    let c = v.get("controller").context("stats missing controller")?;
    let r = v.get("router").context("stats missing router")?;
    Ok(ServiceStats {
        controller: ControllerStats {
            requests: get_u64(c, "requests")?,
            matched: get_u64(c, "matched")?,
            fallbacks: get_u64(c, "fallbacks")?,
            rejected: get_u64(c, "rejected")?,
            cancelled: get_u64(c, "cancelled")?,
            resumed: get_u64(c, "resumed")?,
            epochs_total: get_u64(c, "epochs_total")?,
        },
        router: RouterStats {
            admitted: get_u64(r, "admitted")?,
            shed_expired: get_u64(r, "shed_expired")?,
            shed_capacity: get_u64(r, "shed_capacity")?,
            served: get_u64(r, "served")?,
            depth: get_u64(r, "depth")?,
        },
    })
}

/// A full [`MatchResponse`] (fitness as f32 bits, id as hex, optional
/// snapshot through [`SwarmSnapshot::to_json`]).
pub fn encode_response(resp: &MatchResponse) -> Json {
    Json::obj(vec![
        ("id", hex_u64(resp.id)),
        ("mappings", Json::Arr(resp.mappings.iter().map(|mp| encode_opt_indices(mp)).collect())),
        ("best_fitness", f32_bits(resp.best_fitness)),
        ("epochs_run", Json::from(resp.epochs_run)),
        ("host_seconds", Json::from(resp.host_seconds)),
        ("path", Json::from(resp.path.name())),
        ("resumed", Json::from(resp.resumed)),
        ("snapshot", resp.snapshot.as_ref().map_or(Json::Null, SwarmSnapshot::to_json)),
    ])
}

/// Inverse of [`encode_response`].
pub fn decode_response(v: &Json) -> Result<MatchResponse> {
    let path_name = get_str(v, "path")?;
    Ok(MatchResponse {
        id: get_hex_u64(v, "id")?,
        mappings: v
            .get("mappings")
            .and_then(Json::as_array)
            .context("response missing mappings")?
            .iter()
            .map(decode_opt_indices)
            .collect::<Result<Vec<Mapping>>>()?,
        best_fitness: get_f32_bits(v, "best_fitness")?,
        epochs_run: get_usize(v, "epochs_run")?,
        host_seconds: get_f64(v, "host_seconds")?,
        path: MatchPath::from_name(path_name)
            .with_context(|| format!("unknown match path {path_name:?}"))?,
        resumed: get_bool(v, "resumed")?,
        snapshot: match v.get("snapshot") {
            None | Some(Json::Null) => None,
            Some(snap) => Some(SwarmSnapshot::from_json(snap)?),
        },
    })
}

/// Trace context as `{trace: <hex>, parent: <hex>}` — both words are
/// full u64s, so they travel as 16-digit hex and round-trip bit-exactly
/// (ids and trace words may exceed 2^53).
pub fn encode_trace_ctx(ctx: &TraceCtx) -> Json {
    Json::obj(vec![("trace", hex_u64(ctx.trace_id)), ("parent", hex_u64(ctx.parent))])
}

/// Inverse of [`encode_trace_ctx`].
pub fn decode_trace_ctx(v: &Json) -> Result<TraceCtx> {
    Ok(TraceCtx { trace_id: get_hex_u64(v, "trace")?, parent: get_hex_u64(v, "parent")? })
}

/// One worker-side span (kind by stable name, stamp as hex nanos —
/// worker-local clock, meaningful for ordering within the worker).
fn encode_span(ev: &TraceEvent) -> Json {
    Json::obj(vec![
        ("id", hex_u64(ev.id)),
        ("kind", Json::from(ev.kind.name())),
        ("at_ns", hex_u64(ev.at_nanos)),
        ("terminal", Json::from(ev.terminal)),
        ("detail", Json::from(ev.detail.as_str())),
    ])
}

fn decode_span(v: &Json) -> Result<TraceEvent> {
    let kind_name = get_str(v, "kind")?;
    Ok(TraceEvent {
        id: get_hex_u64(v, "id")?,
        kind: SpanKind::from_name(kind_name)
            .with_context(|| format!("unknown span kind {kind_name:?}"))?,
        at_nanos: get_hex_u64(v, "at_ns")?,
        terminal: get_bool(v, "terminal")?,
        // the router's ingest marks provenance; on the wire it is
        // implicit (every shipped span is remote to the receiver)
        remote: false,
        detail: get_str(v, "detail")?.to_string(),
    })
}

fn encode_spans(spans: &[TraceEvent]) -> Json {
    Json::Arr(spans.iter().map(encode_span).collect())
}

fn decode_spans(v: &Json) -> Result<Vec<TraceEvent>> {
    v.as_array().context("spans must be an array")?.iter().map(decode_span).collect()
}

fn encode_status(status: &ShardStatus) -> Json {
    Json::obj(vec![
        ("queue_depth", Json::from(status.queue_depth)),
        ("in_flight", status.in_flight.map_or(Json::Null, encode_priority)),
        ("in_flight_id", status.in_flight_id.map_or(Json::Null, hex_u64)),
        ("stats", encode_service_stats(&status.stats)),
    ])
}

fn decode_status(v: &Json) -> Result<ShardStatus> {
    Ok(ShardStatus {
        queue_depth: get_usize(v, "queue_depth")?,
        in_flight: match v.get("in_flight") {
            None | Some(Json::Null) => None,
            Some(_) => Some(decode_priority(v, "in_flight")?),
        },
        in_flight_id: match v.get("in_flight_id") {
            None | Some(Json::Null) => None,
            Some(_) => Some(get_hex_u64(v, "in_flight_id")?),
        },
        stats: decode_service_stats(v.get("stats").context("status missing stats")?)?,
    })
}

// ---------------------------------------------------------------------------
// message codec
// ---------------------------------------------------------------------------

fn envelope(t: &str, mut fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("schema", Json::from(WIRE_SCHEMA)), ("t", Json::from(t))];
    all.append(&mut fields);
    Json::obj(all)
}

fn check_envelope(v: &Json) -> Result<&str> {
    let schema = get_str(v, "schema")?;
    if schema != WIRE_SCHEMA {
        let hint = if schema.starts_with("immsched.shard-wire/") {
            " (mixed router/worker versions — redeploy both sides from the same build)"
        } else {
            ""
        };
        bail!("wire schema mismatch: peer speaks {schema:?}, this side {WIRE_SCHEMA:?}{hint}");
    }
    get_str(v, "t")
}

/// Encode one router → shard message.
pub fn encode_msg(msg: &ShardMsg) -> Json {
    match msg {
        ShardMsg::Hello { service, pso } => envelope(
            "hello",
            vec![("service", encode_service_config(service)), ("pso", encode_pso_config(pso))],
        ),
        ShardMsg::Submit { id, problem, priority, timeout, resume, trace } => envelope(
            "submit",
            vec![
                ("id", hex_u64(*id)),
                ("priority", encode_priority(*priority)),
                ("timeout", timeout.map_or(Json::Null, Json::from)),
                ("resume", resume.as_ref().map_or(Json::Null, SwarmSnapshot::to_json)),
                ("trace", trace.as_ref().map_or(Json::Null, encode_trace_ctx)),
                ("problem", encode_problem(problem)),
            ],
        ),
        ShardMsg::Cancel { id } => envelope("cancel", vec![("id", hex_u64(*id))]),
        ShardMsg::Stats => envelope("stats", vec![]),
        ShardMsg::Drain => envelope("drain", vec![]),
    }
}

/// Decode one router → shard message.
pub fn decode_msg(v: &Json) -> Result<ShardMsg> {
    Ok(match check_envelope(v)? {
        "hello" => ShardMsg::Hello {
            service: decode_service_config(v.get("service").context("hello missing service")?)?,
            pso: decode_pso_config(v.get("pso").context("hello missing pso")?)?,
        },
        "submit" => ShardMsg::Submit {
            id: get_hex_u64(v, "id")?,
            problem: decode_problem(v.get("problem").context("submit missing problem")?)?,
            priority: decode_priority(v, "priority")?,
            timeout: match v.get("timeout") {
                None | Some(Json::Null) => None,
                Some(x) => Some(x.as_f64().context("timeout must be a number")?),
            },
            resume: match v.get("resume") {
                None | Some(Json::Null) => None,
                Some(snap) => Some(SwarmSnapshot::from_json(snap)?),
            },
            trace: match v.get("trace") {
                None | Some(Json::Null) => None,
                Some(ctx) => Some(decode_trace_ctx(ctx)?),
            },
        },
        "cancel" => ShardMsg::Cancel { id: get_hex_u64(v, "id")? },
        "stats" => ShardMsg::Stats,
        "drain" => ShardMsg::Drain,
        other => bail!("unknown shard message type {other:?}"),
    })
}

/// Encode one shard → router reply.
pub fn encode_reply(reply: &ShardReply) -> Json {
    match reply {
        ShardReply::Ready { schema } => {
            envelope("ready", vec![("proto", Json::from(schema.as_str()))])
        }
        ShardReply::Response { response, status, spans } => envelope(
            "response",
            vec![
                ("response", encode_response(response)),
                ("status", status.as_ref().map_or(Json::Null, encode_status)),
                ("spans", encode_spans(spans)),
            ],
        ),
        ShardReply::Stats(status) => envelope("stats", vec![("status", encode_status(status))]),
        ShardReply::Drained { answered } => {
            envelope("drained", vec![("answered", Json::from(*answered))])
        }
        ShardReply::Error { context } => {
            envelope("error", vec![("context", Json::from(context.as_str()))])
        }
    }
}

/// Decode one shard → router reply.
pub fn decode_reply(v: &Json) -> Result<ShardReply> {
    Ok(match check_envelope(v)? {
        "ready" => ShardReply::Ready { schema: get_str(v, "proto")?.to_string() },
        "response" => ShardReply::Response {
            response: decode_response(v.get("response").context("reply missing response")?)?,
            status: match v.get("status") {
                None | Some(Json::Null) => None,
                Some(status) => Some(decode_status(status)?),
            },
            spans: match v.get("spans") {
                None | Some(Json::Null) => Vec::new(),
                Some(spans) => decode_spans(spans)?,
            },
        },
        "stats" => {
            ShardReply::Stats(decode_status(v.get("status").context("reply missing status")?)?)
        }
        "drained" => ShardReply::Drained { answered: get_u64(v, "answered")? },
        "error" => ShardReply::Error { context: get_str(v, "context")?.to_string() },
        other => bail!("unknown shard reply type {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};

    fn chain_problem(n: usize, m: usize) -> MatchProblem {
        let qd = gen_chain(n, NodeKind::Compute);
        let gd = gen_chain(m, NodeKind::Universal);
        MatchProblem::from_dags(&qd, &gd)
    }

    #[test]
    fn problem_round_trips() {
        let p = chain_problem(5, 11);
        let back = decode_problem(&encode_problem(&p)).unwrap();
        assert_eq!(back.query, p.query);
        assert_eq!(back.target, p.target);
        assert_eq!(back.mask, p.mask);
    }

    #[test]
    fn configs_round_trip_bit_exactly() {
        let pso = PsoConfig { seed: u64::MAX - 3, repair_budget: 1 << 60, ..Default::default() };
        let back = decode_pso_config(&encode_pso_config(&pso)).unwrap();
        assert_eq!(back.seed, pso.seed, "seeds past 2^53 must survive");
        assert_eq!(back.repair_budget, pso.repair_budget);
        assert_eq!(back.w.to_bits(), pso.w.to_bits());
        let svc = ServiceConfig { queue_depth: 7, epoch_quota: Some(3) };
        let back = decode_service_config(&encode_service_config(&svc)).unwrap();
        assert_eq!((back.queue_depth, back.epoch_quota), (7, Some(3)));
    }

    #[test]
    fn trace_ctx_round_trips_bit_exactly() {
        // both words above 2^53: a float codec would corrupt them
        let ctx = TraceCtx { trace_id: u64::MAX - 7, parent: (1 << 60) + 3 };
        let back = decode_trace_ctx(&encode_trace_ctx(&ctx)).unwrap();
        assert_eq!(back, ctx);
        // and through a full submit frame, including the None case
        for trace in [Some(ctx), None] {
            let msg = ShardMsg::Submit {
                id: u64::MAX - 1,
                problem: chain_problem(3, 6),
                priority: Priority::High,
                timeout: None,
                resume: None,
                trace,
            };
            let back = decode_msg(&encode_msg(&msg)).unwrap();
            let ShardMsg::Submit { id, trace: back_trace, .. } = back else {
                panic!("expected submit")
            };
            assert_eq!(id, u64::MAX - 1);
            assert_eq!(back_trace, trace, "trace context must survive the wire bit-exactly");
        }
    }

    #[test]
    fn reply_spans_round_trip_and_default_empty() {
        let spans = vec![
            TraceEvent {
                id: 42,
                kind: SpanKind::Admit,
                at_nanos: (1 << 62) + 9,
                terminal: false,
                remote: false,
                detail: "evicted=0".to_string(),
            },
            TraceEvent {
                id: 42,
                kind: SpanKind::Slice,
                at_nanos: (1 << 62) + 10,
                terminal: false,
                remote: false,
                detail: "epochs=15".to_string(),
            },
        ];
        let reply = ShardReply::Response {
            response: MatchResponse {
                id: 42,
                mappings: vec![],
                best_fitness: -1.0,
                epochs_run: 15,
                host_seconds: 0.25,
                path: MatchPath::NativeEpoch,
                resumed: false,
                snapshot: None,
            },
            status: None,
            spans: spans.clone(),
        };
        let back = decode_reply(&encode_reply(&reply)).unwrap();
        let ShardReply::Response { spans: back_spans, .. } = back else {
            panic!("expected response")
        };
        assert_eq!(back_spans, spans);
        // a reply without the field decodes to no spans (lenient on
        // absence, strict on malformation — the status precedent)
        let mut doc = encode_reply(&reply);
        if let Json::Obj(fields) = &mut doc {
            fields.retain(|(k, _)| k != "spans");
        }
        let ShardReply::Response { spans: none, .. } = decode_reply(&doc).unwrap() else {
            panic!("expected response")
        };
        assert!(none.is_empty());
    }

    #[test]
    fn frame_round_trip_and_eof() {
        let mut buf = Vec::new();
        let doc = encode_msg(&ShardMsg::Stats);
        write_frame(&mut buf, &doc).unwrap();
        write_frame(&mut buf, &encode_msg(&ShardMsg::Drain)).unwrap();
        let mut r = &buf[..];
        let first = decode_msg(&read_frame(&mut r).unwrap().unwrap()).unwrap();
        assert!(matches!(first, ShardMsg::Stats));
        let second = decode_msg(&read_frame(&mut r).unwrap().unwrap()).unwrap();
        assert!(matches!(second, ShardMsg::Drain));
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF between frames");
    }

    #[test]
    fn truncated_frames_are_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &encode_msg(&ShardMsg::Stats)).unwrap();
        // EOF inside the payload
        let mut cut = &buf[..buf.len() - 3];
        assert!(read_frame(&mut cut).unwrap_err().to_string().contains("truncated"));
        // EOF inside the length prefix
        let mut cut = &buf[..2];
        assert!(read_frame(&mut cut).unwrap_err().to_string().contains("length prefix"));
        // oversized length prefix is rejected before allocation
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_be_bytes());
        huge.extend_from_slice(b"xx");
        let mut r = &huge[..];
        assert!(read_frame(&mut r).unwrap_err().to_string().contains("cap"));
    }

    #[test]
    fn schema_mismatch_fails_loudly() {
        let mut doc = encode_msg(&ShardMsg::Stats);
        if let Json::Obj(fields) = &mut doc {
            fields[0].1 = Json::from("immsched.shard-wire/v0");
        }
        let err = decode_msg(&doc).unwrap_err().to_string();
        assert!(err.contains("schema mismatch"), "{err}");
    }
}
