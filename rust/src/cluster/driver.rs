//! Open-loop, trace-driven arrival driver for the cluster.
//!
//! Requests are generated *ahead of time* from the simulator's own trace
//! generator ([`build_trace`] over the `workload::models` task mix, with
//! Poisson or MMPP-bursty urgent arrivals via
//! [`crate::scheduler::ArrivalProcess`]) and then replayed against a
//! live [`MatchCluster`] on the wall clock — open loop: submission times
//! never wait for completions, exactly the "unpredictable task
//! arrivals" regime the paper targets.
//!
//! The driver collects per-shard latency / SLO-miss / shed / preemption
//! metrics and resubmits cancelled requests with their persisted
//! snapshots, so a run exercises the whole preempt → persist → resume
//! loop.  Since the fleet supervision layer landed, the driver runs
//! through a [`SupervisedFleet`] rather than the raw cluster — a shard
//! dying mid-run becomes a replay (counted in the report's
//! [`FailoverStats`]) instead of a hang.  `bench_cluster` and
//! `immsched cluster` are thin wrappers around [`schedule_from_trace`]
//! + [`run_open_loop`].

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::accel::{build_target_graph, Platform, PlatformKind};
use crate::coordinator::{MatchPath, MatchProblem, MatchResponse, RequestId};
use crate::obs::metrics::well;
use crate::obs::trace::{terminal, SpanKind};
use crate::scheduler::{build_trace, ArrivalProcess, Priority, TraceConfig};
use crate::util::stats::Summary;
use crate::util::table::{fmt_time, Table};
use crate::workload::{TilingConfig, WorkloadClass};

use super::{ClusterStats, FailoverStats, ShardId, SupervisedFleet};

/// Knobs for one driver run.
#[derive(Clone, Copy, Debug)]
pub struct DriverConfig {
    /// Workload class whose models the trace mixes.
    pub class: WorkloadClass,
    /// Modeled platform the target graphs are built for.
    pub platform: PlatformKind,
    /// Urgent arrival process (Poisson / bursty MMPP).
    pub process: ArrivalProcess,
    /// Urgent base arrival rate λ (tasks/s).
    pub arrival_rate: f64,
    /// Trace horizon (s of modeled arrival time).
    pub horizon: f64,
    /// Background streams feeding steady load.
    pub background_tasks: usize,
    /// Deadline = arrival + factor × isolated exec estimate.
    pub deadline_factor: f64,
    pub tiling: TilingConfig,
    pub seed: u64,
    /// Wall-clock compression: trace gaps are multiplied by this before
    /// sleeping (0 = submit as fast as possible).
    pub time_scale: f64,
    /// Resubmit cancelled requests with their persisted snapshots until
    /// they complete (bounded), exercising the warm-start path.
    pub resubmit_cancelled: bool,
}

impl Default for DriverConfig {
    fn default() -> Self {
        Self {
            class: WorkloadClass::Simple,
            platform: PlatformKind::Edge,
            process: ArrivalProcess::bursty_default(),
            arrival_rate: 120.0,
            horizon: 0.1,
            background_tasks: 2,
            deadline_factor: 50.0,
            tiling: TilingConfig { max_tiles: 12, split_factor: 2 },
            seed: 42,
            time_scale: 0.0,
            resubmit_cancelled: true,
        }
    }
}

/// One scheduled submission of the open-loop run.
#[derive(Clone, Debug)]
pub struct TimedRequest {
    /// Modeled arrival time (s from run start).
    pub at: f64,
    pub problem: MatchProblem,
    pub priority: Priority,
    /// Relative SLO budget (s from submission); `None` = best-effort.
    pub timeout: Option<f64>,
}

/// Build the open-loop request schedule by replaying a simulator trace:
/// every task becomes one match request (its tile DAG against the
/// platform's all-preemptible target graph), keeping arrival time,
/// priority and deadline slack.
pub fn schedule_from_trace(cfg: &DriverConfig) -> Vec<TimedRequest> {
    let platform = Platform::get(cfg.platform);
    let trace_cfg = TraceConfig {
        class: cfg.class,
        background_tasks: cfg.background_tasks,
        arrival_rate: cfg.arrival_rate,
        process: cfg.process,
        horizon: cfg.horizon,
        deadline_factor: cfg.deadline_factor,
        batch: 16,
        tiling: cfg.tiling,
        seed: cfg.seed,
    };
    let preemptible = vec![true; platform.engines];
    let (target, _) = build_target_graph(&platform, &preemptible);
    build_trace(&trace_cfg, &platform)
        .into_iter()
        .map(|task| TimedRequest {
            at: task.arrival,
            problem: MatchProblem::from_dags(&task.tiles.dag, &target),
            priority: task.priority,
            timeout: task.deadline.map(|d| (d - task.arrival).max(1e-6)),
        })
        .collect()
}

/// One answered request of a driver run.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    pub id: RequestId,
    /// Shard that produced the *final* response.
    pub shard: ShardId,
    pub priority: Priority,
    pub path: MatchPath,
    /// The final episode warm-started from a persisted snapshot.
    pub resumed: bool,
    /// Epochs of the final episode.
    pub epochs_run: usize,
    /// Submit → final-response wall latency (s), across resubmissions.
    pub latency: f64,
    /// Latency exceeded the request's SLO budget (or it was shed /
    /// left cancelled).
    pub slo_miss: bool,
    /// Times the request was resubmitted after a cancellation.
    pub resubmits: u32,
}

/// Aggregated result of one open-loop run.
#[derive(Clone, Debug)]
pub struct DriverReport {
    pub outcomes: Vec<RequestOutcome>,
    /// Final cluster telemetry (per-shard stats, resume-store traffic).
    pub cluster: ClusterStats,
    /// Supervision telemetry: probes, shard deaths, replays, sheds at
    /// the capacity floor.
    pub failover: FailoverStats,
    /// Wall-clock of the whole run (s).
    pub wall_seconds: f64,
}

impl DriverReport {
    pub fn submitted(&self) -> usize {
        self.outcomes.len()
    }

    pub fn count_path(&self, path: MatchPath) -> usize {
        self.outcomes.iter().filter(|o| o.path == path).count()
    }

    pub fn served(&self) -> usize {
        self.outcomes
            .iter()
            .filter(|o| !matches!(o.path, MatchPath::Shed | MatchPath::Cancelled))
            .count()
    }

    pub fn resumed(&self) -> usize {
        self.outcomes.iter().filter(|o| o.resumed).count()
    }

    pub fn slo_misses(&self) -> usize {
        self.outcomes.iter().filter(|o| o.slo_miss).count()
    }

    /// Mean end-to-end latency across final responses (s) — what the
    /// bench's `obs_overhead` block compares between paired runs.
    pub fn mean_latency(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().map(|o| o.latency).sum::<f64>() / self.outcomes.len() as f64
    }

    /// Latency percentile across final responses (s); `q` in [0, 100].
    pub fn latency_percentile(&self, q: f64) -> f64 {
        let mut series = Summary::from_iter(self.outcomes.iter().map(|o| o.latency));
        if series.count() == 0 {
            return 0.0;
        }
        series.percentile(q)
    }

    /// Per-shard summary table (the driver's console output).
    pub fn table(&self) -> Table {
        let mut t = Table::new("cluster open-loop run (per shard)").header(&[
            "shard",
            "routed",
            "served",
            "shed",
            "preempted",
            "resumed",
            "queue depth",
            "p50 latency",
            "p95 latency",
        ]);
        for (shard, stats) in self.cluster.shards.iter().enumerate() {
            let mut lat = Summary::from_iter(
                self.outcomes.iter().filter(|o| o.shard == shard).map(|o| o.latency),
            );
            let (p50, p95) = if lat.count() == 0 {
                (0.0, 0.0)
            } else {
                (lat.percentile(50.0), lat.percentile(95.0))
            };
            t.row(vec![
                shard.to_string(),
                self.cluster.routed.get(shard).copied().unwrap_or(0).to_string(),
                stats.router.served.to_string(),
                (stats.router.shed_expired + stats.router.shed_capacity).to_string(),
                stats.controller.cancelled.to_string(),
                stats.controller.resumed.to_string(),
                stats.router.depth.to_string(),
                fmt_time(p50),
                fmt_time(p95),
            ]);
        }
        t.row(vec![
            "total".into(),
            self.submitted().to_string(),
            self.served().to_string(),
            self.count_path(MatchPath::Shed).to_string(),
            self.cluster.preemptions().to_string(),
            self.resumed().to_string(),
            "-".into(),
            fmt_time(self.latency_percentile(50.0)),
            fmt_time(self.latency_percentile(95.0)),
        ]);
        t
    }
}

/// In-flight bookkeeping for one submitted request (the fleet tracks
/// the ticket; the driver tracks only the id).
struct Pending {
    id: RequestId,
    problem: MatchProblem,
    priority: Priority,
    timeout: Option<f64>,
    submitted: Instant,
    resubmits: u32,
}

/// Bound on preempt→resume cycles per request (epoch-quota slicing can
/// legitimately cancel the same episode several times).
const MAX_RESUBMITS: u32 = 16;

/// Replay `schedule` against the fleet on the wall clock.  Every
/// submitted request is answered exactly once in the report (served,
/// shed, or cancelled); with `resubmit_cancelled`, cancelled requests
/// are resubmitted with their snapshots until they complete or the
/// resubmit bound is hit.  A shard dying mid-run is the fleet's
/// problem — the driver just sees (replayed) responses.
pub fn run_open_loop(
    fleet: &SupervisedFleet,
    schedule: &[TimedRequest],
    cfg: &DriverConfig,
) -> Result<DriverReport> {
    let started = Instant::now();
    let mut pending: Vec<Pending> = Vec::new();
    let mut outcomes: Vec<RequestOutcome> = Vec::new();
    let mut prev_at = 0.0f64;

    for req in schedule {
        if cfg.time_scale > 0.0 {
            let gap = (req.at - prev_at).max(0.0) * cfg.time_scale;
            if gap > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(gap.min(1.0)));
            }
        }
        prev_at = req.at;
        let id = fleet.submit(req.problem.clone(), req.priority, req.timeout)?;
        pending.push(Pending {
            id,
            problem: req.problem.clone(),
            priority: req.priority,
            timeout: req.timeout,
            submitted: Instant::now(),
            resubmits: 0,
        });
        drain_ready(fleet, cfg, &mut pending, &mut outcomes)?;
    }

    // settle: poll the in-flight set until every submission (including
    // warm-start resubmissions and failover replays) has a final
    // response
    while !pending.is_empty() {
        drain_ready(fleet, cfg, &mut pending, &mut outcomes)?;
        if !pending.is_empty() {
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    Ok(DriverReport {
        outcomes,
        cluster: fleet.cluster().stats(),
        failover: fleet.failover(),
        wall_seconds: started.elapsed().as_secs_f64(),
    })
}

/// Non-blocking sweep over the in-flight set.
fn drain_ready(
    fleet: &SupervisedFleet,
    cfg: &DriverConfig,
    pending: &mut Vec<Pending>,
    outcomes: &mut Vec<RequestOutcome>,
) -> Result<()> {
    let mut i = 0;
    while i < pending.len() {
        // capture the serving shard before the poll — the record is
        // gone once the response surfaces
        let shard = fleet.shard_of(pending[i].id).unwrap_or(0);
        if let Some(resp) = fleet.try_wait(pending[i].id) {
            let p = pending.swap_remove(i);
            settle(fleet, cfg, p, shard, resp, pending, outcomes)?;
        } else {
            i += 1;
        }
    }
    Ok(())
}

/// Record a final response — or turn a cancellation into a warm-start
/// resubmission (the fleet's `try_wait` has already persisted the
/// snapshot into the cluster's resume store).
fn settle(
    fleet: &SupervisedFleet,
    cfg: &DriverConfig,
    p: Pending,
    shard: ShardId,
    resp: MatchResponse,
    pending: &mut Vec<Pending>,
    outcomes: &mut Vec<RequestOutcome>,
) -> Result<()> {
    if cfg.resubmit_cancelled
        && resp.path == MatchPath::Cancelled
        && resp.snapshot.is_some()
        && p.resubmits < MAX_RESUBMITS
    {
        // a failed resubmission (e.g. routing hit a shard that just
        // died) keeps its snapshot in the store and the cancellation
        // becomes this request's final answer — never lose the request
        match fleet.resubmit(p.id, p.problem.clone(), p.priority, p.timeout) {
            Ok(()) => {
                pending.push(Pending {
                    id: p.id,
                    problem: p.problem,
                    priority: p.priority,
                    timeout: p.timeout,
                    submitted: p.submitted,
                    resubmits: p.resubmits + 1,
                });
                return Ok(());
            }
            Err(e) => crate::log_warn!("resubmit of request {} failed: {e:#}", p.id),
        }
    }
    let latency = p.submitted.elapsed().as_secs_f64();
    let slo_miss = match resp.path {
        MatchPath::Shed | MatchPath::Cancelled => true,
        _ => p.timeout.is_some_and(|t| latency > t),
    };
    // the driver is the terminal-span arbiter: exactly one terminal
    // event per request life, stamped where the outcome is classified
    let kind = match resp.path {
        MatchPath::Shed => SpanKind::Shed,
        MatchPath::Cancelled => SpanKind::Cancelled,
        MatchPath::Rejected => SpanKind::Failed,
        _ => SpanKind::Done,
    };
    terminal(resp.id, kind, || {
        format!("path={} slo_miss={slo_miss} resubmits={}", resp.path.name(), p.resubmits)
    });
    well::CLUSTER_TERMINAL.inc();
    well::CLUSTER_LATENCY.observe_us((latency * 1e6) as u64);
    outcomes.push(RequestOutcome {
        id: resp.id,
        shard,
        priority: p.priority,
        path: resp.path,
        resumed: resp.resumed,
        epochs_run: resp.epochs_run,
        latency,
        slo_miss,
        resubmits: p.resubmits,
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{
        ClusterConfig, LeastQueueDepth, MatchCluster, SupervisorConfig,
    };
    use crate::matcher::PsoConfig;
    use std::sync::Arc;

    #[test]
    fn schedule_replays_trace_with_deadline_slack() {
        let cfg = DriverConfig {
            horizon: 0.05,
            arrival_rate: 100.0,
            seed: 3,
            ..Default::default()
        };
        let schedule = schedule_from_trace(&cfg);
        assert!(!schedule.is_empty());
        for w in schedule.windows(2) {
            assert!(w[0].at <= w[1].at, "schedule must be sorted by arrival");
        }
        assert!(schedule.iter().any(|r| r.priority == Priority::Urgent));
        for r in schedule.iter().filter(|r| r.priority == Priority::Urgent) {
            assert!(r.timeout.is_some_and(|t| t > 0.0), "urgent requests carry SLO budgets");
        }
    }

    /// A small end-to-end open-loop run: every scheduled request is
    /// answered exactly once (conservation), and the report's totals add
    /// up.
    #[test]
    fn open_loop_run_conserves_requests() {
        let dcfg = DriverConfig {
            horizon: 0.02,
            arrival_rate: 150.0,
            background_tasks: 1,
            seed: 9,
            time_scale: 0.0,
            ..Default::default()
        };
        let schedule = schedule_from_trace(&dcfg);
        let cluster = Arc::new(
            MatchCluster::spawn(
                ClusterConfig {
                    shards: 2,
                    pso: PsoConfig { seed: 6, ..Default::default() },
                    ..Default::default()
                },
                Box::new(LeastQueueDepth),
            )
            .unwrap(),
        );
        let fleet = SupervisedFleet::new(cluster, SupervisorConfig::default());
        let report = run_open_loop(&fleet, &schedule, &dcfg).unwrap();
        assert_eq!(report.submitted(), schedule.len(), "lost or duplicated responses");
        let mut ids: Vec<RequestId> = report.outcomes.iter().map(|o| o.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), schedule.len(), "duplicate final responses for one id");
        assert!(report.served() > 0, "nothing served");
        assert_eq!(report.failover.shards_failed, 0, "healthy run must not fail shards");
        assert!(!report.table().is_empty());
        fleet.drain().unwrap();
    }
}
