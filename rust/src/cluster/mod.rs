//! `cluster/`: sharded multi-accelerator serving — the front router over
//! N shards (one modeled accelerator each), toward the production-scale
//! north star.
//!
//! * [`MatchCluster`] — owns one [`ShardTransport`] per shard and hands
//!   out globally unique request ids; every submission is routed by a
//!   pluggable [`RoutePolicy`] ([`RoundRobin`], [`LeastQueueDepth`], or
//!   [`DeadlineAware`] with cross-shard preemption) using the
//!   transport-reported [`ShardStatus`] load signal.
//! * [`transport`] — the shard boundary itself: [`InProcessShard`]
//!   (one `MatchService` thread, the zero-copy path) and
//!   [`ProcessShard`] (an `immsched shard-worker` child process spoken
//!   to over the framed [`wire`] protocol).  Mixed fleets are fine —
//!   routing never sees the difference.
//! * [`wire`] — the versioned, schema-tagged codec ([`ShardMsg`] /
//!   [`ShardReply`]) with bit-exact snapshot serialization, so a
//!   preempted episode's warm-start state migrates across a process
//!   boundary and resumes bit-identically.
//! * [`ResumeStore`] — a cancelled episode's S*/S̄ barrier snapshot is
//!   persisted keyed by request id; [`MatchCluster::resubmit`]
//!   warm-starts the resubmission from it (same shard or migrated),
//!   so preemption costs the victim its *place*, not its *progress*.
//! * [`driver`] — the open-loop, trace-driven arrival driver (Poisson
//!   and MMPP-style bursty processes over the `workload::models` task
//!   mix) that feeds the cluster and collects per-shard latency /
//!   SLO-miss / shed / preemption metrics — the `bench_cluster` binary
//!   and the `immsched cluster` CLI subcommand run it.
//! * [`supervise`] — fleet supervision over the transports: heartbeat
//!   liveness probes, automatic failover of in-flight requests off
//!   dead or wedged shards (warm-starting from the resume store), and
//!   graceful degradation to shedding below a capacity floor.
//! * [`chaos`] — [`FaultInjectingTransport`], a deterministic seeded
//!   decorator over any transport that injects delays, dropped
//!   replies, undecodable frames and worker kills from a scripted
//!   schedule, so the failover paths are exercised by ordinary
//!   `cargo test`.
//! * [`net`] — shards on the network: [`SocketShard`] dials a remote
//!   `immsched shard-listen` worker over TCP or Unix-domain sockets
//!   (reconnect-with-resume on a severed link), [`WorkerRegistry`]
//!   speaks the `immsched.fleet-wire/v1` join/heartbeat/leave protocol
//!   so the router *discovers* workers, and [`ElasticScaler`] grows
//!   and retires shard slots against the observed queue depth.
//! * [`experiment`] — replicated sweep campaigns over the stack:
//!   declarative parameter grids, seeded replications merged in
//!   deterministic cell order, per-policy LBT search, and the quota
//!   tournament that sizes epoch slices adaptively from the observed
//!   arrival rate.
//!
//! Request lifecycle: **route → submit (transport) → admit → engine
//! chain → outcome**, with `Cancelled` outcomes feeding the resume
//! store.

pub mod chaos;
pub mod driver;
pub mod experiment;
pub mod net;
pub mod policy;
pub mod resume;
pub mod supervise;
pub mod transport;
pub mod wire;

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{MatchProblem, MatchResponse, RequestId, ServiceConfig, ServiceStats};
use crate::matcher::{PsoConfig, SwarmSnapshot};
use crate::obs::metrics::{publish_service, well};
use crate::obs::trace::{span_with, SpanKind};
use crate::scheduler::Priority;

use transport::lock_recover;

pub use chaos::{ChaosFault, ChaosSchedule, ChaosStats, FaultInjectingTransport};
pub use policy::{
    policy_by_name, DeadlineAware, LeastQueueDepth, RoundRobin, RoutePolicy, ShardId, ShardView,
    DEGRADED_QUEUE_DEPTH,
};
pub use net::{
    announce, shards_from_registry, spawn_shard_listener, Announcer, ElasticScaler,
    ElasticityConfig, ListenConfig, ListenerChild, NetAddr, NetStream, ReconnectConfig,
    RegistryServer, ShardListener, SocketShard, WorkerRegistry,
};
pub use resume::{ResumeStats, ResumeStore};
pub use supervise::{FailoverStats, SupervisedFleet, SupervisorConfig};
pub use transport::{
    FrameFault, InProcessShard, ProcessShard, ShardTransport, TransportConfig,
};
pub use wire::{ShardMsg, ShardReply, ShardStatus};

/// Cluster-wide knobs.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    /// Shard count — one shard per modeled accelerator.
    pub shards: usize,
    /// Per-shard admission knobs (queue depth, epoch quota).
    pub service: ServiceConfig,
    /// Matcher configuration shared by every shard's engine chain.
    pub pso: PsoConfig,
    /// Resume-store capacity (snapshots kept for warm restarts).
    pub resume_capacity: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            shards: 2,
            service: ServiceConfig::default(),
            pso: PsoConfig::default(),
            resume_capacity: 1024,
        }
    }
}

/// Aggregate cluster telemetry.
#[derive(Clone, Debug, Default)]
pub struct ClusterStats {
    /// Per-shard service stats, indexed by shard id.
    pub shards: Vec<ServiceStats>,
    /// Requests routed to each shard (submission counts).
    pub routed: Vec<u64>,
    /// Resume-store traffic (saved / taken / evicted snapshots).
    pub resume: ResumeStats,
}

impl ClusterStats {
    /// Episodes preempted/cancelled at an epoch barrier, cluster-wide.
    pub fn preemptions(&self) -> u64 {
        self.shards.iter().map(|s| s.controller.cancelled).sum()
    }

    /// Episodes that warm-started from a persisted snapshot.
    pub fn resumes(&self) -> u64 {
        self.shards.iter().map(|s| s.controller.resumed).sum()
    }

    /// Requests shed by admission, cluster-wide.
    pub fn shed(&self) -> u64 {
        self.shards.iter().map(|s| s.router.shed_expired + s.router.shed_capacity).sum()
    }
}

/// A routed submission: which shard serves it, plus a handle on that
/// shard's transport.  Waiting (blocking or polling) through the
/// cluster ticket automatically persists any snapshot a response
/// carries — from a cancelled episode, or handed back untouched by a
/// shed resubmission — into the cluster's [`ResumeStore`].
pub struct ClusterTicket {
    pub id: RequestId,
    pub shard: ShardId,
    transport: Arc<dyn ShardTransport>,
    store: Arc<ResumeStore>,
}

impl ClusterTicket {
    /// Block until the shard answers; a cancelled episode's snapshot is
    /// persisted for [`MatchCluster::resubmit`] before returning.
    pub fn wait(self) -> Result<MatchResponse> {
        let resp = self.transport.wait_response(self.id)?;
        stash(&self.store, &resp);
        Ok(resp)
    }

    /// Non-blocking poll; persists a cancelled episode's snapshot when
    /// the response arrives.
    pub fn try_wait(&self) -> Option<MatchResponse> {
        let resp = self.transport.try_response(self.id)?;
        stash(&self.store, &resp);
        Some(resp)
    }

    /// Stop the episode at its next epoch barrier (or before it starts).
    pub fn cancel(&self) {
        self.transport.cancel(self.id);
    }

    /// Whether the transport serving this ticket still considers
    /// itself alive (supervision's cheap per-poll liveness check).
    pub fn healthy(&self) -> bool {
        self.transport.healthy()
    }

    /// Whether this ticket's reply can no longer arrive (dropped by a
    /// dead connection) — supervision replays lost tickets elsewhere.
    pub fn lost(&self) -> bool {
        self.transport.lost(self.id)
    }
}

fn stash(store: &ResumeStore, resp: &MatchResponse) {
    if let Some(snapshot) = &resp.snapshot {
        store.save(resp.id, snapshot.clone());
    }
}

/// One shard's cached status: when it was probed, and what the probe
/// said (`None` = the probe failed, i.e. a dead or wedged worker — the
/// failure is cached too, so a dead shard costs one control timeout per
/// TTL window instead of one per submission).
type StatusSlot = Option<(Instant, Option<ShardStatus>)>;

/// How long a cached [`ShardStatus`] stays fresh before `views()` /
/// `stats()` re-probe.  The supervision heartbeat force-refreshes via
/// [`MatchCluster::probe`], so under a running [`SupervisedFleet`] the
/// routing hot path almost never pays a status round-trip.
const DEFAULT_STATUS_TTL: Duration = Duration::from_millis(50);

/// The front router: N shards behind transports, one policy, one
/// resume store.  Transports sit behind per-shard locks so supervision
/// can swap in a respawned replacement without tearing the cluster
/// down.
pub struct MatchCluster {
    shards: Vec<Mutex<Arc<dyn ShardTransport>>>,
    status_cache: Vec<Mutex<StatusSlot>>,
    status_ttl: Duration,
    policy: Mutex<Box<dyn RoutePolicy>>,
    store: Arc<ResumeStore>,
    routed: Vec<AtomicU64>,
    next_id: AtomicU64,
    start: Instant,
}

impl MatchCluster {
    /// Spawn `cfg.shards` in-process services behind `policy` (the
    /// zero-serialization default).
    pub fn spawn(cfg: ClusterConfig, policy: Box<dyn RoutePolicy>) -> Result<Self> {
        let shards = cfg.shards.max(1);
        let mut transports: Vec<Arc<dyn ShardTransport>> = Vec::with_capacity(shards);
        for _ in 0..shards {
            transports.push(Arc::new(InProcessShard::spawn(cfg.service, cfg.pso)?));
        }
        Ok(Self::with_transports(transports, policy, cfg.resume_capacity))
    }

    /// Spawn `cfg.shards` out-of-process `shard-worker` children (see
    /// [`transport::worker_binary`] for how the worker binary is
    /// found).  Same config, same policies, same resume semantics —
    /// only the boundary differs.
    pub fn spawn_process_shards(cfg: ClusterConfig, policy: Box<dyn RoutePolicy>) -> Result<Self> {
        let bin = transport::worker_binary()?;
        Self::spawn_process_shards_at(&bin, cfg, policy)
    }

    /// [`Self::spawn_process_shards`] from an explicit worker binary
    /// (tests pass `env!("CARGO_BIN_EXE_immsched")`).
    pub fn spawn_process_shards_at(
        bin: &Path,
        cfg: ClusterConfig,
        policy: Box<dyn RoutePolicy>,
    ) -> Result<Self> {
        let shards = cfg.shards.max(1);
        let mut transports: Vec<Arc<dyn ShardTransport>> = Vec::with_capacity(shards);
        for _ in 0..shards {
            transports.push(Arc::new(ProcessShard::spawn_at(bin, cfg.service, cfg.pso)?));
        }
        Ok(Self::with_transports(transports, policy, cfg.resume_capacity))
    }

    /// Assemble a cluster over caller-provided transports — mixed
    /// fleets (in-process + out-of-process shards) route identically.
    pub fn with_transports(
        transports: Vec<Arc<dyn ShardTransport>>,
        policy: Box<dyn RoutePolicy>,
        resume_capacity: usize,
    ) -> Self {
        assert!(!transports.is_empty(), "a cluster needs at least one shard");
        let routed = (0..transports.len()).map(|_| AtomicU64::new(0)).collect();
        let status_cache = (0..transports.len()).map(|_| Mutex::new(None)).collect();
        Self {
            shards: transports.into_iter().map(Mutex::new).collect(),
            status_cache,
            status_ttl: DEFAULT_STATUS_TTL,
            policy: Mutex::new(policy),
            store: Arc::new(ResumeStore::with_capacity(resume_capacity)),
            routed,
            next_id: AtomicU64::new(1),
            start: Instant::now(),
        }
    }

    /// Override how long cached shard statuses stay fresh (tests use
    /// `Duration::ZERO` to force a probe per call).
    pub fn set_status_ttl(&mut self, ttl: Duration) {
        self.status_ttl = ttl;
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The transport currently serving `shard` (a clone of the handle —
    /// supervision may swap the slot afterwards, in which case the
    /// returned transport keeps serving its already-issued tickets).
    pub fn transport(&self, shard: ShardId) -> Arc<dyn ShardTransport> {
        let shard = shard.min(self.shards.len() - 1);
        Arc::clone(&lock_recover(&self.shards[shard]))
    }

    /// Swap a respawned replacement transport into `shard`'s slot and
    /// invalidate its cached status.  Tickets issued against the old
    /// transport keep their own handle; only *new* routing sees the
    /// replacement.
    pub fn replace_transport(&self, shard: ShardId, transport: Arc<dyn ShardTransport>) {
        let shard = shard.min(self.shards.len() - 1);
        *lock_recover(&self.shards[shard]) = transport;
        *lock_recover(&self.status_cache[shard]) = None;
    }

    /// Whether `shard`'s transport considers itself alive (cheap local
    /// check — no control round-trip).
    pub fn shard_healthy(&self, shard: ShardId) -> bool {
        self.transport(shard).healthy()
    }

    /// Seconds since cluster start.
    pub fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// The cluster's resume store (snapshot persistence for cancelled
    /// episodes).
    pub fn resume_store(&self) -> &ResumeStore {
        &self.store
    }

    /// Transport kind per shard (telemetry: `"in-process"` /
    /// `"process"`).
    pub fn transport_kinds(&self) -> Vec<&'static str> {
        (0..self.shards.len()).map(|s| self.transport(s).kind()).collect()
    }

    /// Force-refresh `shard`'s cached status with a live control
    /// round-trip.  The supervision heartbeat calls this on its own
    /// cadence, which is what keeps `views()` / `stats()` off the
    /// per-submit status tax; a failed probe is cached too (as a
    /// degraded entry), so a dead worker costs one control timeout per
    /// TTL window, not one per routing decision.
    pub fn probe(&self, shard: ShardId) -> Result<ShardStatus> {
        let shard = shard.min(self.shards.len() - 1);
        let res = self.transport(shard).status();
        *lock_recover(&self.status_cache[shard]) =
            Some((Instant::now(), res.as_ref().ok().cloned()));
        res
    }

    /// Cached-or-fresh status for `shard`: fold in any status a reply
    /// piggybacked since the last look (wire v3 pushes one on every
    /// response, so a busy shard refreshes its cache for free), then
    /// serve the cache while it is within the TTL, otherwise probe.
    /// `None` means the most recent probe failed (dead or wedged
    /// worker).
    fn fetch_status(&self, shard: ShardId) -> Option<ShardStatus> {
        {
            let pushed = self.transport(shard).take_pushed_status();
            let mut slot = lock_recover(&self.status_cache[shard]);
            if let Some((at, status)) = pushed {
                let newer = slot.as_ref().map_or(true, |(prev, _)| at > *prev);
                if newer {
                    *slot = Some((at, Some(status)));
                }
            }
            if let Some((at, status)) = slot.as_ref() {
                if at.elapsed() <= self.status_ttl {
                    return status.clone();
                }
            }
        }
        match self.probe(shard) {
            Ok(status) => Some(status),
            Err(e) => {
                crate::log_warn!("shard {shard} status probe failed: {e:#}");
                None
            }
        }
    }

    /// Current per-shard routing views (the policy input; also useful
    /// for dashboards/tests), served from the TTL status cache.  A
    /// shard whose transport cannot report — a dead worker — shows up
    /// with an effectively infinite queue depth so load-aware policies
    /// avoid it.
    pub fn views(&self) -> Vec<ShardView> {
        (0..self.shards.len())
            .map(|shard| match self.fetch_status(shard) {
                Some(status) => ShardView {
                    shard,
                    queue_depth: status.queue_depth,
                    in_flight: status.in_flight,
                    stats: status.stats,
                },
                None => ShardView {
                    shard,
                    queue_depth: DEGRADED_QUEUE_DEPTH,
                    in_flight: None,
                    stats: ServiceStats::default(),
                },
            })
            .collect()
    }

    pub fn stats(&self) -> ClusterStats {
        let shards: Vec<ServiceStats> = (0..self.shards.len())
            .map(|s| self.fetch_status(s).map(|st| st.stats).unwrap_or_default())
            .collect();
        // unify the per-shard stats structs into the metrics registry
        // as views (no-op with the plane disabled)
        for (shard, stats) in shards.iter().enumerate() {
            publish_service(shard, stats);
        }
        ClusterStats {
            shards,
            routed: self.routed.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            resume: self.store.stats(),
        }
    }

    /// Submit a new request: the policy picks the shard, the cluster
    /// assigns a globally unique id.  `timeout` is relative (seconds
    /// from now); the chosen shard anchors it to its own clock — the
    /// reason absolute deadlines never cross the transport boundary.
    pub fn submit(
        &self,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
    ) -> Result<ClusterTicket> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let shard = self.route(priority, timeout);
        self.submit_inner(shard, id, problem, priority, timeout, None)
    }

    /// Shard-addressable submission (bypasses the policy — fillers,
    /// tests, and debugging).
    pub fn submit_to(
        &self,
        shard: ShardId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
    ) -> Result<ClusterTicket> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.submit_inner(shard, id, problem, priority, timeout, None)
    }

    /// Resubmit a previously answered request under its original id.
    /// If a cancelled episode persisted a snapshot for `id`, the new
    /// episode warm-starts from it — on whichever shard the policy now
    /// picks (resume survives migration, including across a process
    /// boundary).
    pub fn resubmit(
        &self,
        id: RequestId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
    ) -> Result<ClusterTicket> {
        let resume = self.store.take(id);
        self.resubmit_carrying(id, problem, priority, timeout, resume)
    }

    /// [`Self::resubmit`] with an explicitly supplied warm-start
    /// snapshot instead of a destructive store take.  Fleet supervision
    /// uses this to replay a request whose shard died: the fleet holds
    /// its own copy of the last barrier snapshot, so a second crash
    /// mid-replay can still warm-start from the same barrier.
    pub fn resubmit_carrying(
        &self,
        id: RequestId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
        resume: Option<SwarmSnapshot>,
    ) -> Result<ClusterTicket> {
        let shard = self.route(priority, timeout);
        self.submit_inner(shard, id, problem, priority, timeout, resume)
    }

    /// Reserve a globally unique request id without submitting anything
    /// — supervision mints ids for requests it must answer on the
    /// cluster's behalf (e.g. shedding at the capacity floor).
    pub fn allocate_request_id(&self) -> RequestId {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Drain every shard: in-flight work finishes, worker processes
    /// exit.  Dropping the cluster does this implicitly; calling it
    /// explicitly surfaces drain errors instead of swallowing them.
    pub fn drain(&self) -> Result<()> {
        for shard in 0..self.shards.len() {
            self.transport(shard)
                .drain()
                .map_err(|e| e.context(format!("draining shard {shard}")))?;
        }
        Ok(())
    }

    fn route(&self, priority: Priority, timeout: Option<f64>) -> ShardId {
        let views = self.views();
        let shard = self.policy.lock().unwrap().route(priority, timeout, &views);
        shard.min(self.shards.len() - 1)
    }

    fn submit_inner(
        &self,
        shard: ShardId,
        id: RequestId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
        resume: Option<SwarmSnapshot>,
    ) -> Result<ClusterTicket> {
        let shard = shard.min(self.shards.len() - 1);
        let transport = self.transport(shard);
        transport.submit(id, problem, priority, timeout, resume)?;
        self.routed[shard].fetch_add(1, Ordering::Relaxed);
        well::CLUSTER_ROUTED.inc();
        span_with(id, SpanKind::Route, || {
            format!("shard={shard} kind={}", transport.kind())
        });
        Ok(ClusterTicket { id, shard, transport, store: Arc::clone(&self.store) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};

    fn chain_problem(n: usize, m: usize) -> MatchProblem {
        let qd = gen_chain(n, NodeKind::Compute);
        let gd = gen_chain(m, NodeKind::Universal);
        MatchProblem::from_dags(&qd, &gd)
    }

    #[test]
    fn round_robin_cluster_serves_all_shards() {
        let cfg = ClusterConfig {
            shards: 3,
            pso: PsoConfig { seed: 4, ..Default::default() },
            ..Default::default()
        };
        let cluster = MatchCluster::spawn(cfg, Box::<RoundRobin>::default()).unwrap();
        assert_eq!(cluster.transport_kinds(), vec!["in-process"; 3]);
        let mut tickets = Vec::new();
        for _ in 0..6 {
            tickets.push(cluster.submit(chain_problem(4, 8), Priority::Normal, None).unwrap());
        }
        let shards_used: std::collections::BTreeSet<ShardId> =
            tickets.iter().map(|t| t.shard).collect();
        assert_eq!(shards_used.len(), 3, "round-robin must touch every shard");
        for t in tickets {
            let resp = t.wait().unwrap();
            assert!(resp.matched());
        }
        let stats = cluster.stats();
        assert_eq!(stats.routed.iter().sum::<u64>(), 6);
        assert_eq!(stats.routed, vec![2, 2, 2]);
    }

    #[test]
    fn cluster_ids_are_globally_unique() {
        let cluster =
            MatchCluster::spawn(ClusterConfig::default(), Box::<RoundRobin>::default()).unwrap();
        let a = cluster.submit(chain_problem(3, 6), Priority::Normal, None).unwrap();
        let b = cluster.submit(chain_problem(3, 6), Priority::Normal, None).unwrap();
        assert_ne!(a.id, b.id);
        let (ra, rb) = (a.wait().unwrap(), b.wait().unwrap());
        assert_ne!(ra.id, rb.id, "responses must echo the cluster-assigned ids");
    }

    #[test]
    fn mixed_transport_fleet_routes_uniformly() {
        // two in-process shards behind the transport trait directly —
        // the cluster must treat hand-assembled fleets like spawned ones
        let pso = PsoConfig { seed: 12, ..Default::default() };
        let transports: Vec<Arc<dyn ShardTransport>> = vec![
            Arc::new(InProcessShard::spawn(ServiceConfig::default(), pso).unwrap()),
            Arc::new(InProcessShard::spawn(ServiceConfig::default(), pso).unwrap()),
        ];
        let cluster =
            MatchCluster::with_transports(transports, Box::<RoundRobin>::default(), 64);
        let a = cluster.submit(chain_problem(4, 8), Priority::Normal, None).unwrap();
        let b = cluster.submit(chain_problem(4, 8), Priority::Normal, None).unwrap();
        assert_ne!(a.shard, b.shard);
        assert!(a.wait().unwrap().matched());
        assert!(b.wait().unwrap().matched());
    }
}
