//! `cluster/`: sharded multi-accelerator serving — the front router over
//! N [`MatchService`] shards (one per modeled accelerator), toward the
//! production-scale north star.
//!
//! * [`MatchCluster`] — owns the shards and hands out globally unique
//!   request ids; every submission is routed by a pluggable
//!   [`RoutePolicy`] ([`RoundRobin`], [`LeastQueueDepth`], or
//!   [`DeadlineAware`] with cross-shard preemption) using the shards'
//!   non-blocking [`ServiceStats`].
//! * [`ResumeStore`] — a cancelled episode's S*/S̄ barrier snapshot is
//!   persisted keyed by request id; [`MatchCluster::resubmit`]
//!   warm-starts the resubmission from it (same shard or migrated),
//!   so preemption costs the victim its *place*, not its *progress*.
//! * [`driver`] — the open-loop, trace-driven arrival driver (Poisson
//!   and MMPP-style bursty processes over the `workload::models` task
//!   mix) that feeds the cluster and collects per-shard latency /
//!   SLO-miss / shed / preemption metrics — the `bench_cluster` binary
//!   and the `immsched cluster` CLI subcommand run it.
//!
//! Request lifecycle: **route → submit (shard) → admit → engine chain →
//! outcome**, with `Cancelled` outcomes feeding the resume store.

pub mod driver;
pub mod policy;
pub mod resume;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::{
    MatchProblem, MatchResponse, MatchService, MatchTicket, RequestId, ServiceConfig,
    ServiceStats, SubmitOptions,
};
use crate::matcher::PsoConfig;
use crate::scheduler::Priority;

pub use policy::{
    policy_by_name, DeadlineAware, LeastQueueDepth, RoundRobin, RoutePolicy, ShardId, ShardView,
};
pub use resume::{ResumeStats, ResumeStore};

/// Cluster-wide knobs.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    /// Shard count — one [`MatchService`] per modeled accelerator.
    pub shards: usize,
    /// Per-shard admission knobs (queue depth, epoch quota).
    pub service: ServiceConfig,
    /// Matcher configuration shared by every shard's engine chain.
    pub pso: PsoConfig,
    /// Resume-store capacity (snapshots kept for warm restarts).
    pub resume_capacity: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            shards: 2,
            service: ServiceConfig::default(),
            pso: PsoConfig::default(),
            resume_capacity: 1024,
        }
    }
}

/// Aggregate cluster telemetry.
#[derive(Clone, Debug, Default)]
pub struct ClusterStats {
    /// Per-shard service stats, indexed by shard id.
    pub shards: Vec<ServiceStats>,
    /// Requests routed to each shard (submission counts).
    pub routed: Vec<u64>,
    /// Resume-store traffic (saved / taken / evicted snapshots).
    pub resume: ResumeStats,
}

impl ClusterStats {
    /// Episodes preempted/cancelled at an epoch barrier, cluster-wide.
    pub fn preemptions(&self) -> u64 {
        self.shards.iter().map(|s| s.controller.cancelled).sum()
    }

    /// Episodes that warm-started from a persisted snapshot.
    pub fn resumes(&self) -> u64 {
        self.shards.iter().map(|s| s.controller.resumed).sum()
    }

    /// Requests shed by admission, cluster-wide.
    pub fn shed(&self) -> u64 {
        self.shards.iter().map(|s| s.router.shed_expired + s.router.shed_capacity).sum()
    }
}

/// A routed submission: which shard serves it, plus the underlying
/// service ticket.  Waiting (blocking or polling) through the cluster
/// ticket automatically persists any snapshot a response carries —
/// from a cancelled episode, or handed back untouched by a shed
/// resubmission — into the cluster's [`ResumeStore`].
pub struct ClusterTicket {
    pub id: RequestId,
    pub shard: ShardId,
    ticket: MatchTicket,
    store: Arc<ResumeStore>,
}

impl ClusterTicket {
    /// Block until the shard answers; a cancelled episode's snapshot is
    /// persisted for [`MatchCluster::resubmit`] before returning.
    pub fn wait(self) -> Result<MatchResponse> {
        let resp = self.ticket.wait()?;
        stash(&self.store, &resp);
        Ok(resp)
    }

    /// Non-blocking poll; persists a cancelled episode's snapshot when
    /// the response arrives.
    pub fn try_wait(&self) -> Option<MatchResponse> {
        let resp = self.ticket.try_wait()?;
        stash(&self.store, &resp);
        Some(resp)
    }

    /// Stop the episode at its next epoch barrier (or before it starts).
    pub fn cancel(&self) {
        self.ticket.cancel();
    }
}

fn stash(store: &ResumeStore, resp: &MatchResponse) {
    if let Some(snapshot) = &resp.snapshot {
        store.save(resp.id, snapshot.clone());
    }
}

/// The front router: N shards, one policy, one resume store.
pub struct MatchCluster {
    shards: Vec<MatchService>,
    policy: Mutex<Box<dyn RoutePolicy>>,
    store: Arc<ResumeStore>,
    routed: Vec<AtomicU64>,
    next_id: AtomicU64,
    start: Instant,
}

impl MatchCluster {
    /// Spawn `cfg.shards` services behind `policy`.
    pub fn spawn(cfg: ClusterConfig, policy: Box<dyn RoutePolicy>) -> Result<Self> {
        let shards = cfg.shards.max(1);
        let mut services = Vec::with_capacity(shards);
        for _ in 0..shards {
            services.push(MatchService::spawn_configured(cfg.service, cfg.pso)?);
        }
        Ok(Self {
            shards: services,
            policy: Mutex::new(policy),
            store: Arc::new(ResumeStore::with_capacity(cfg.resume_capacity)),
            routed: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            next_id: AtomicU64::new(1),
            start: Instant::now(),
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Seconds since cluster start.
    pub fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// The cluster's resume store (snapshot persistence for cancelled
    /// episodes).
    pub fn resume_store(&self) -> &ResumeStore {
        &self.store
    }

    /// Current per-shard routing views (the policy input; also useful
    /// for dashboards/tests).
    pub fn views(&self) -> Vec<ShardView> {
        self.shards
            .iter()
            .enumerate()
            .map(|(shard, svc)| {
                let stats = svc.stats();
                ShardView {
                    shard,
                    queue_depth: stats.router.depth as usize,
                    in_flight: svc.in_flight(),
                    stats,
                }
            })
            .collect()
    }

    pub fn stats(&self) -> ClusterStats {
        ClusterStats {
            shards: self.shards.iter().map(|s| s.stats()).collect(),
            routed: self.routed.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            resume: self.store.stats(),
        }
    }

    /// Submit a new request: the policy picks the shard, the cluster
    /// assigns a globally unique id.  `timeout` is relative (seconds
    /// from now) and is converted to the chosen shard's absolute clock.
    pub fn submit(
        &self,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
    ) -> Result<ClusterTicket> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let shard = self.route(priority, timeout);
        self.submit_inner(shard, id, problem, priority, timeout, None)
    }

    /// Shard-addressable submission (bypasses the policy — fillers,
    /// tests, and debugging).
    pub fn submit_to(
        &self,
        shard: ShardId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
    ) -> Result<ClusterTicket> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.submit_inner(shard, id, problem, priority, timeout, None)
    }

    /// Resubmit a previously answered request under its original id.
    /// If a cancelled episode persisted a snapshot for `id`, the new
    /// episode warm-starts from it — on whichever shard the policy now
    /// picks (resume survives migration).
    pub fn resubmit(
        &self,
        id: RequestId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
    ) -> Result<ClusterTicket> {
        let resume = self.store.take(id);
        let shard = self.route(priority, timeout);
        self.submit_inner(shard, id, problem, priority, timeout, resume)
    }

    fn route(&self, priority: Priority, timeout: Option<f64>) -> ShardId {
        let views = self.views();
        let shard = self.policy.lock().unwrap().route(priority, timeout, &views);
        shard.min(self.shards.len() - 1)
    }

    fn submit_inner(
        &self,
        shard: ShardId,
        id: RequestId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
        resume: Option<crate::matcher::SwarmSnapshot>,
    ) -> Result<ClusterTicket> {
        let shard = shard.min(self.shards.len() - 1);
        let svc = &self.shards[shard];
        let deadline = timeout.map(|t| svc.now() + t);
        let ticket =
            svc.submit_with(problem, priority, deadline, SubmitOptions { id: Some(id), resume })?;
        self.routed[shard].fetch_add(1, Ordering::Relaxed);
        Ok(ClusterTicket { id, shard, ticket, store: Arc::clone(&self.store) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};

    fn chain_problem(n: usize, m: usize) -> MatchProblem {
        let qd = gen_chain(n, NodeKind::Compute);
        let gd = gen_chain(m, NodeKind::Universal);
        MatchProblem::from_dags(&qd, &gd)
    }

    #[test]
    fn round_robin_cluster_serves_all_shards() {
        let cfg = ClusterConfig {
            shards: 3,
            pso: PsoConfig { seed: 4, ..Default::default() },
            ..Default::default()
        };
        let cluster = MatchCluster::spawn(cfg, Box::<RoundRobin>::default()).unwrap();
        let mut tickets = Vec::new();
        for _ in 0..6 {
            tickets.push(cluster.submit(chain_problem(4, 8), Priority::Normal, None).unwrap());
        }
        let shards_used: std::collections::HashSet<ShardId> =
            tickets.iter().map(|t| t.shard).collect();
        assert_eq!(shards_used.len(), 3, "round-robin must touch every shard");
        for t in tickets {
            let resp = t.wait().unwrap();
            assert!(resp.matched());
        }
        let stats = cluster.stats();
        assert_eq!(stats.routed.iter().sum::<u64>(), 6);
        assert_eq!(stats.routed, vec![2, 2, 2]);
    }

    #[test]
    fn cluster_ids_are_globally_unique() {
        let cluster =
            MatchCluster::spawn(ClusterConfig::default(), Box::<RoundRobin>::default()).unwrap();
        let a = cluster.submit(chain_problem(3, 6), Priority::Normal, None).unwrap();
        let b = cluster.submit(chain_problem(3, 6), Priority::Normal, None).unwrap();
        assert_ne!(a.id, b.id);
        let (ra, rb) = (a.wait().unwrap(), b.wait().unwrap());
        assert_ne!(ra.id, rb.id, "responses must echo the cluster-assigned ids");
    }
}
