//! Pluggable shard-selection policies for the [`super::MatchCluster`]
//! front router.
//!
//! A policy sees one [`ShardView`] per shard — built from the
//! transport-reported [`crate::cluster::ShardStatus`] (queue depth,
//! in-flight episode priority, full [`ServiceStats`]), never from
//! `MatchService` internals, so in-process and out-of-process shards
//! are routed identically — and picks the shard for one submission.
//! Three implementations ship:
//!
//! * [`RoundRobin`] — the baseline spreader;
//! * [`LeastQueueDepth`] — load-aware: fewest queued + in-flight
//!   requests wins (PREMA-style consolidated dispatch needs exactly
//!   this runtime signal next to the static plan);
//! * [`DeadlineAware`] — priority/deadline-aware with **cross-shard
//!   preemption**: a hot request prefers an idle shard, else the shard
//!   whose in-flight victim has the *lowest* priority strictly below
//!   its own — routing there triggers the service's epoch-barrier
//!   preemption, so the hottest work always lands where it displaces
//!   the least important episode.

use crate::coordinator::ServiceStats;
use crate::scheduler::Priority;

/// Shard index within one cluster.
pub type ShardId = usize;

/// Load reported for a shard whose transport failed a status query (a
/// dead or wedged worker): effectively infinite queue depth, so
/// load-aware policies steer new work away from it while supervision
/// fails its in-flight requests over.
pub const DEGRADED_QUEUE_DEPTH: usize = usize::MAX / 4;

/// One shard's routing-relevant state, read without blocking the
/// shard's controller thread.
#[derive(Clone, Debug)]
pub struct ShardView {
    pub shard: ShardId,
    /// Queued requests not yet popped for service.
    pub queue_depth: usize,
    /// Priority of the episode currently occupying the controller.
    pub in_flight: Option<Priority>,
    /// Full service telemetry (router + controller counters).
    pub stats: ServiceStats,
}

impl ShardView {
    /// Queued plus in-flight load.
    pub fn load(&self) -> usize {
        self.queue_depth.saturating_add(usize::from(self.in_flight.is_some()))
    }

    /// Whether this view is the degraded placeholder for a shard whose
    /// transport could not report (dead or wedged worker).
    pub fn is_degraded(&self) -> bool {
        self.queue_depth >= DEGRADED_QUEUE_DEPTH
    }
}

/// A shard-selection policy.  `route` must return a valid index into
/// `shards` (the cluster clamps it defensively).
pub trait RoutePolicy: Send {
    fn name(&self) -> &'static str;
    fn route(
        &mut self,
        priority: Priority,
        deadline: Option<f64>,
        shards: &[ShardView],
    ) -> ShardId;
}

/// Construct a shipped policy by its CLI name (`round-robin`,
/// `least-queue`, `deadline-aware`) — the single parsing point shared by
/// `immsched cluster` and `bench_cluster`.
pub fn policy_by_name(name: &str) -> Option<Box<dyn RoutePolicy>> {
    Some(match name {
        "round-robin" => Box::<RoundRobin>::default(),
        "least-queue" => Box::new(LeastQueueDepth),
        "deadline-aware" => Box::new(DeadlineAware),
        _ => return None,
    })
}

/// Strict rotation over the shards, ignoring load.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoutePolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _: Priority, _: Option<f64>, shards: &[ShardView]) -> ShardId {
        let shard = self.next % shards.len().max(1);
        self.next = self.next.wrapping_add(1);
        shard
    }
}

/// Fewest queued + in-flight requests wins (ties → lowest shard id, so
/// the choice is deterministic).
#[derive(Debug, Default)]
pub struct LeastQueueDepth;

impl RoutePolicy for LeastQueueDepth {
    fn name(&self) -> &'static str {
        "least-queue"
    }

    fn route(&mut self, _: Priority, _: Option<f64>, shards: &[ShardView]) -> ShardId {
        shards
            .iter()
            .min_by_key(|v| (v.load(), v.shard))
            .map(|v| v.shard)
            .unwrap_or(0)
    }
}

/// Priority/deadline-aware routing with cross-shard preemption.
///
/// For a request that outranks at least one in-flight episode:
/// 1. an **idle** shard (nothing queued, nothing in flight) serves it
///    with zero displacement;
/// 2. otherwise the shard whose in-flight victim has the **lowest**
///    priority strictly below the request's — submitting there cancels
///    the weakest victim at its next epoch barrier (the victim's
///    snapshot lands in the resume store for a warm restart);
/// 3. otherwise plain least-load.
///
/// Best-effort requests (nothing to preempt) always take least-load.
#[derive(Debug, Default)]
pub struct DeadlineAware;

impl RoutePolicy for DeadlineAware {
    fn name(&self) -> &'static str {
        "deadline-aware"
    }

    fn route(
        &mut self,
        priority: Priority,
        _deadline: Option<f64>,
        shards: &[ShardView],
    ) -> ShardId {
        if let Some(idle) = shards.iter().find(|v| v.load() == 0) {
            return idle.shard;
        }
        // weakest preemptable victim: lowest in-flight priority strictly
        // below ours, tie-broken toward the shallower queue
        let victim = shards
            .iter()
            .filter_map(|v| {
                v.in_flight
                    .filter(|&p| p < priority)
                    .map(|p| (p, v.queue_depth, v.shard))
            })
            .min();
        if let Some((_, _, shard)) = victim {
            return shard;
        }
        LeastQueueDepth.route(priority, None, shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(shard: ShardId, queue_depth: usize, in_flight: Option<Priority>) -> ShardView {
        ShardView { shard, queue_depth, in_flight, stats: ServiceStats::default() }
    }

    #[test]
    fn degraded_views_lose_every_load_comparison() {
        let shards = vec![
            ShardView { queue_depth: DEGRADED_QUEUE_DEPTH, ..view(0, 0, None) },
            view(1, 50, Some(Priority::Urgent)),
        ];
        assert!(shards[0].is_degraded() && !shards[1].is_degraded());
        assert_eq!(
            LeastQueueDepth.route(Priority::Normal, None, &shards),
            1,
            "a dead shard must lose to any live shard, however loaded"
        );
    }

    #[test]
    fn round_robin_rotates() {
        let shards = vec![view(0, 0, None), view(1, 0, None), view(2, 0, None)];
        let mut rr = RoundRobin::default();
        let picks: Vec<ShardId> =
            (0..5).map(|_| rr.route(Priority::Normal, None, &shards)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn least_queue_prefers_shallowest_then_lowest_id() {
        let shards = vec![
            view(0, 3, Some(Priority::Normal)),
            view(1, 1, None),
            view(2, 1, None),
        ];
        assert_eq!(LeastQueueDepth.route(Priority::Normal, None, &shards), 1);
    }

    #[test]
    fn deadline_aware_prefers_idle_shard() {
        let shards = vec![view(0, 2, Some(Priority::Normal)), view(1, 0, None)];
        assert_eq!(DeadlineAware.route(Priority::Urgent, Some(1.0), &shards), 1);
    }

    #[test]
    fn deadline_aware_targets_weakest_victim_for_preemption() {
        // no idle shard: the urgent request must land on the shard whose
        // in-flight episode is Background (the weakest victim), not the
        // one running Normal work
        let shards = vec![
            view(0, 0, Some(Priority::Normal)),
            view(1, 2, Some(Priority::Background)),
            view(2, 1, Some(Priority::Urgent)),
        ];
        assert_eq!(DeadlineAware.route(Priority::Urgent, Some(1.0), &shards), 1);
    }

    #[test]
    fn deadline_aware_background_falls_back_to_least_load() {
        let shards = vec![
            view(0, 2, Some(Priority::Background)),
            view(1, 1, Some(Priority::Background)),
        ];
        // a Background request outranks nothing: least-load wins
        assert_eq!(DeadlineAware.route(Priority::Background, None, &shards), 1);
    }
}
