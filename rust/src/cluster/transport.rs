//! Shard transports: how the [`super::MatchCluster`] front router
//! reaches one shard, abstracted over the process boundary.
//!
//! [`ShardTransport`] is the routing-facing contract — submit /
//! resubmit (a submit carrying a resume snapshot) / cancel / stats /
//! drain, mirroring the [`wire::ShardMsg`] protocol verbs.  Two
//! implementations ship:
//!
//! * [`InProcessShard`] — wraps a [`MatchService`] thread directly (the
//!   PR 4 cluster path, zero serialization);
//! * [`ProcessShard`] — spawns an `immsched shard-worker` child
//!   process hosting one `MatchService`, and speaks the framed
//!   [`wire`] protocol over the child's stdio.  A demux thread routes
//!   out-of-order responses back to waiters by request id.
//!
//! [`worker_serve`] is the other half of [`ProcessShard`]: the loop a
//! worker process runs over its stdin/stdout.  It lives here (not in
//! `main.rs`) so integration tests can exercise the exact production
//! loop through any `Read`/`Write` pair.
//!
//! The cluster holds `Arc<dyn ShardTransport>` per shard, so mixed
//! fleets (some shards in-process, some out-of-process) are routed
//! identically — policies only ever see transport-reported
//! [`ShardStatus`] load, never `MatchService` internals.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{
    CancelToken, MatchPath, MatchProblem, MatchResponse, MatchService, MatchTicket, RequestId,
    ServiceConfig, SubmitOptions,
};
use crate::matcher::{PsoConfig, SwarmSnapshot};
use crate::obs::trace::{self, TraceCtx, TraceEvent};
use crate::scheduler::Priority;

use super::wire::{
    self, decode_msg, decode_reply, encode_msg, encode_reply, read_frame, write_frame,
    ShardMsg, ShardReply, ShardStatus,
};

/// Environment override for the worker binary `ProcessShard::spawn`
/// launches (useful when the router binary is not `immsched` itself).
pub const WORKER_BIN_ENV: &str = "IMMSCHED_WORKER_BIN";

/// How long a control round-trip (stats, drain) may take before the
/// shard is declared unresponsive.
const CONTROL_TIMEOUT: Duration = Duration::from_secs(30);

/// How often the worker sweeps its pending tickets while idle.
const IDLE_POLL: Duration = Duration::from_millis(2);
/// Sweep cadence while episodes are in flight (snappy completions).
const BUSY_POLL: Duration = Duration::from_micros(200);

/// Timing knobs for one transport endpoint.  The defaults are the
/// constants the transports shipped with; supervision tests shrink
/// `control_timeout` so a wedged worker is detected in milliseconds
/// instead of half a minute.
#[derive(Clone, Copy, Debug)]
pub struct TransportConfig {
    /// Budget for one control round-trip (handshake, stats, drain)
    /// before the shard is declared unresponsive.
    pub control_timeout: Duration,
    /// Worker-side sweep cadence over pending tickets while idle.
    pub idle_poll: Duration,
    /// Worker-side sweep cadence while episodes are in flight.
    pub busy_poll: Duration,
}

impl Default for TransportConfig {
    fn default() -> Self {
        Self { control_timeout: CONTROL_TIMEOUT, idle_poll: IDLE_POLL, busy_poll: BUSY_POLL }
    }
}

/// Take a transport lock even if another thread panicked while holding
/// it.  The maps behind these locks (tickets, cancel tokens, demuxed
/// responses, the writer handle) are valid after any partial update, so
/// poison recovery degrades at most the one request the panicking
/// thread owned — instead of wedging every later caller of the shard.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Trace context a transport attaches to an outgoing submit when
/// tracing is on: the globally unique request id doubles as the trace
/// id (parent 0 = root), so the worker's spans stitch by id on return.
pub(crate) fn submit_trace_ctx(id: RequestId) -> Option<TraceCtx> {
    trace::enabled().then_some(TraceCtx { trace_id: id, parent: 0 })
}

/// Worker-side spans to piggyback on a response: drained from the
/// worker's tracer so a long-lived worker neither re-ships nor
/// accumulates them.  Empty (and allocation-free) with tracing off.
fn drain_worker_spans(id: RequestId) -> Vec<TraceEvent> {
    if trace::enabled() {
        trace::tracer().take_for(id)
    } else {
        Vec::new()
    }
}

/// A deliberately malformed frame, injected by the chaos transport to
/// exercise the connection-fault paths a corrupt peer would trigger.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameFault {
    /// A well-framed payload that is not decodable wire JSON — the
    /// receiver treats the whole connection as poisoned.
    Garbage,
    /// A length prefix promising more bytes than are ever sent — the
    /// receiver blocks mid-frame and the connection wedges (control
    /// round-trips start timing out).
    Truncated,
}

/// One shard as the router sees it.  All methods are callable from any
/// thread; responses are keyed by the globally unique request id the
/// cluster assigns.
pub trait ShardTransport: Send + Sync {
    /// Transport kind for telemetry (`"in-process"` / `"process"`).
    fn kind(&self) -> &'static str;

    /// Submit one request.  `timeout` is relative seconds from now (the
    /// shard anchors it to its own clock); `resume` makes this a
    /// resubmission that warm-starts from the snapshot.
    fn submit(
        &self,
        id: RequestId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
        resume: Option<SwarmSnapshot>,
    ) -> Result<()>;

    /// Cancel `id` at its next epoch barrier (no-op if already done).
    fn cancel(&self, id: RequestId);

    /// Current load + telemetry — the routing policies' only input.
    fn status(&self) -> Result<ShardStatus>;

    /// Non-blocking poll for `id`'s final answer.
    fn try_response(&self, id: RequestId) -> Option<MatchResponse>;

    /// Block until `id`'s final answer arrives.
    fn wait_response(&self, id: RequestId) -> Result<MatchResponse>;

    /// Finish answering everything submitted, reject further
    /// submissions, and release the shard's execution resources.
    /// Already-produced responses stay consumable afterwards.  Errors
    /// if the shard cannot settle within the control timeout.
    fn drain(&self) -> Result<()>;

    /// Cheap liveness hint: `false` once the transport *knows* its
    /// shard can no longer answer (worker exited, connection fault).
    /// Supervision fails over immediately on `false` instead of
    /// waiting out a heartbeat miss streak.  Transports with no such
    /// signal report `true`.
    fn healthy(&self) -> bool {
        true
    }

    /// Whether `id` can no longer be answered on this transport (its
    /// reply was lost or the connection died before it was produced).
    /// Supervision replays lost requests elsewhere.  Default: never.
    fn lost(&self, _id: RequestId) -> bool {
        false
    }

    /// Forcibly terminate the shard's execution resources *now* — no
    /// drain, in-flight episodes die un-answered.  The chaos transport
    /// uses this as its kill-the-child fault; supervision uses it to
    /// put a wedged worker out of its misery before respawning.  No-op
    /// for transports with nothing to kill (in-process shards).
    fn abort(&self) {}

    /// Chaos hook: deliver a deliberately malformed frame to the
    /// shard, exercising the undecodable-frame / wedged-connection
    /// fault paths.  Errors on transports without a frame boundary.
    fn inject_frame_fault(&self, fault: FrameFault) -> Result<()> {
        let _ = fault;
        bail!("transport {:?} has no frame boundary to corrupt", self.kind())
    }

    /// Take the freshest [`ShardStatus`] a reply piggybacked (since
    /// wire v3 every `Response` frame carries one), stamped with its
    /// arrival instant.  The cluster folds it into the TTL status
    /// cache before deciding whether a probe is due, so completions
    /// refresh routing for free.  Default: transports with no push
    /// channel report `None`.
    fn take_pushed_status(&self) -> Option<(Instant, ShardStatus)> {
        None
    }
}

// ---------------------------------------------------------------------------
// in-process transport
// ---------------------------------------------------------------------------

/// The zero-copy transport: one [`MatchService`] thread in this
/// process, tickets demuxed by request id.
pub struct InProcessShard {
    svc: MatchService,
    /// Pending tickets by id; an entry leaves when its response is
    /// consumed (an abandoned ticket stays until the shard drops).
    tickets: Mutex<BTreeMap<RequestId, MatchTicket>>,
    /// Cancel tokens stay reachable while [`Self::wait_response`] holds
    /// the ticket out of the map.
    cancels: Mutex<BTreeMap<RequestId, CancelToken>>,
    /// Set by [`ShardTransport::drain`]: later submissions are rejected,
    /// mirroring a drained worker's closed stdin.
    draining: AtomicBool,
    /// Timing knobs (only `control_timeout` applies in-process).
    tcfg: TransportConfig,
}

impl InProcessShard {
    pub fn spawn(cfg: ServiceConfig, pso: PsoConfig) -> Result<Self> {
        Self::spawn_with(cfg, pso, TransportConfig::default())
    }

    /// [`Self::spawn`] with explicit transport timing knobs.
    pub fn spawn_with(cfg: ServiceConfig, pso: PsoConfig, tcfg: TransportConfig) -> Result<Self> {
        Ok(Self {
            svc: MatchService::spawn_configured(cfg, pso)?,
            tickets: Mutex::new(BTreeMap::new()),
            cancels: Mutex::new(BTreeMap::new()),
            draining: AtomicBool::new(false),
            tcfg,
        })
    }

    fn forget(&self, id: RequestId) {
        lock_recover(&self.cancels).remove(&id);
    }
}

impl ShardTransport for InProcessShard {
    fn kind(&self) -> &'static str {
        "in-process"
    }

    fn submit(
        &self,
        id: RequestId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
        resume: Option<SwarmSnapshot>,
    ) -> Result<()> {
        if self.draining.load(Ordering::Acquire) {
            bail!("shard drained: no further submissions accepted");
        }
        let deadline = timeout.map(|t| self.svc.now() + t);
        let opts = SubmitOptions { id: Some(id), resume };
        let ticket = self.svc.submit_with(problem, priority, deadline, opts)?;
        lock_recover(&self.cancels).insert(id, ticket.cancel_token());
        lock_recover(&self.tickets).insert(id, ticket);
        Ok(())
    }

    fn cancel(&self, id: RequestId) {
        if let Some(token) = lock_recover(&self.cancels).get(&id) {
            token.cancel();
        }
    }

    fn status(&self) -> Result<ShardStatus> {
        let stats = self.svc.stats();
        let inventory = self.svc.in_flight_request();
        Ok(ShardStatus {
            queue_depth: stats.router.depth as usize,
            in_flight: inventory.map(|(_, p)| p),
            in_flight_id: inventory.map(|(id, _)| id),
            stats,
        })
    }

    fn try_response(&self, id: RequestId) -> Option<MatchResponse> {
        let mut tickets = lock_recover(&self.tickets);
        let resp = tickets.get(&id)?.try_wait()?;
        tickets.remove(&id);
        drop(tickets);
        self.forget(id);
        Some(resp)
    }

    fn wait_response(&self, id: RequestId) -> Result<MatchResponse> {
        let ticket = lock_recover(&self.tickets)
            .remove(&id)
            .with_context(|| format!("request {id} unknown or already answered"))?;
        let resp = ticket.wait();
        self.forget(id);
        resp
    }

    fn drain(&self) -> Result<()> {
        // mirror the worker contract: stop accepting, then wait until
        // everything submitted has been answered by the service (the
        // responses stay in their tickets for later consumption)
        self.draining.store(true, Ordering::Release);
        let start = Instant::now();
        let mut idle_streak = 0u32;
        loop {
            let stats = self.svc.stats();
            if stats.router.depth == 0 && self.svc.in_flight().is_none() {
                // two consecutive idle observations, so a submission
                // racing the drain call has cleared the channel→queue
                // hop before we declare the shard settled
                idle_streak += 1;
                if idle_streak >= 2 {
                    return Ok(());
                }
            } else {
                idle_streak = 0;
            }
            if start.elapsed() > self.tcfg.control_timeout {
                bail!("in-process shard did not settle within {:?}", self.tcfg.control_timeout);
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

// ---------------------------------------------------------------------------
// out-of-process transport
// ---------------------------------------------------------------------------

/// Demux state shared between callers and the reader thread.
struct Demux {
    state: Mutex<DemuxState>,
    arrived: Condvar,
    /// Freshest reply-piggybacked status, for
    /// [`ShardTransport::take_pushed_status`].
    pushed: Mutex<Option<(Instant, ShardStatus)>>,
}

struct DemuxState {
    responses: BTreeMap<RequestId, MatchResponse>,
    /// The worker exited (or its stream broke); waiting is hopeless.
    dead: bool,
}

/// A shard hosted by a child `shard-worker` process, reached over
/// length-prefixed [`wire`] frames on the child's stdio.
pub struct ProcessShard {
    child: Mutex<Child>,
    /// `None` after shutdown — dropping the handle closes the worker's
    /// stdin, which the worker treats as a drain request.
    writer: Mutex<Option<ChildStdin>>,
    demux: Arc<Demux>,
    /// Serializes control round-trips (stats/drain) so concurrent
    /// callers cannot interleave each other's replies.
    control: Mutex<ControlChannels>,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
    tcfg: TransportConfig,
}

struct ControlChannels {
    stats_rx: mpsc::Receiver<ShardStatus>,
    drained_rx: mpsc::Receiver<u64>,
}

impl ProcessShard {
    /// Spawn a worker with this binary's `shard-worker` subcommand (or
    /// the [`WORKER_BIN_ENV`] override / a sibling `immsched` binary —
    /// see [`worker_binary`]).
    pub fn spawn(cfg: ServiceConfig, pso: PsoConfig) -> Result<Self> {
        Self::spawn_at(&worker_binary()?, cfg, pso)
    }

    /// Spawn a worker from an explicit binary path (tests pass
    /// `env!("CARGO_BIN_EXE_immsched")`).
    pub fn spawn_at(bin: &Path, cfg: ServiceConfig, pso: PsoConfig) -> Result<Self> {
        Self::spawn_at_with(bin, cfg, pso, TransportConfig::default())
    }

    /// [`Self::spawn_at`] with explicit transport timing knobs, so
    /// supervision tests can shrink the control timeout from its 30 s
    /// default and detect a wedged worker in milliseconds.
    pub fn spawn_at_with(
        bin: &Path,
        cfg: ServiceConfig,
        pso: PsoConfig,
        tcfg: TransportConfig,
    ) -> Result<Self> {
        let mut child = Command::new(bin)
            .arg("shard-worker")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning shard worker {}", bin.display()))?;
        let reap = |mut child: Child, e: anyhow::Error| -> anyhow::Error {
            let _ = child.kill();
            let _ = child.wait();
            e
        };
        let (Some(mut stdin), Some(mut stdout)) = (child.stdin.take(), child.stdout.take())
        else {
            let e = anyhow::anyhow!("shard worker spawned without piped stdio");
            return Err(reap(child, e));
        };

        // handshake before the demux thread owns stdout: Hello carries
        // the shard config, Ready proves the schema matches.  The first
        // read runs on a helper thread so a worker that never answers
        // fails the spawn after the control timeout instead of hanging
        // it; stdout comes back through the channel for the demux
        // thread.
        if let Err(e) = write_frame(&mut stdin, &encode_msg(&ShardMsg::Hello { service: cfg, pso }))
        {
            return Err(reap(child, e));
        }
        let (hs_tx, hs_rx) = mpsc::channel();
        std::thread::spawn(move || {
            let first = read_frame(&mut stdout);
            let _ = hs_tx.send((first, stdout));
        });
        let (first, stdout) = match hs_rx.recv_timeout(tcfg.control_timeout) {
            Ok(pair) => pair,
            Err(_) => {
                let e = anyhow::anyhow!(
                    "shard worker did not answer the hello within {:?}",
                    tcfg.control_timeout
                );
                return Err(reap(child, e));
            }
        };
        let handshake = (|| -> Result<()> {
            let first = first?.context("shard worker exited before answering the hello")?;
            match decode_reply(&first)? {
                ShardReply::Ready { schema } if schema == wire::WIRE_SCHEMA => Ok(()),
                ShardReply::Ready { schema } => {
                    bail!("shard worker speaks {schema:?}, expected {:?}", wire::WIRE_SCHEMA)
                }
                ShardReply::Error { context } => {
                    bail!("shard worker rejected the hello: {context}")
                }
                other => bail!("unexpected handshake reply {other:?}"),
            }
        })();
        if let Err(e) = handshake {
            return Err(reap(child, e));
        }

        let demux = Arc::new(Demux {
            state: Mutex::new(DemuxState { responses: BTreeMap::new(), dead: false }),
            arrived: Condvar::new(),
            pushed: Mutex::new(None),
        });
        let (stats_tx, stats_rx) = mpsc::channel();
        let (drained_tx, drained_rx) = mpsc::channel();
        let reader_demux = Arc::clone(&demux);
        let reader = std::thread::Builder::new()
            .name("immsched-shard-demux".into())
            .spawn(move || demux_loop(stdout, reader_demux, stats_tx, drained_tx))?;

        Ok(Self {
            child: Mutex::new(child),
            writer: Mutex::new(Some(stdin)),
            demux,
            control: Mutex::new(ControlChannels { stats_rx, drained_rx }),
            reader: Mutex::new(Some(reader)),
            tcfg,
        })
    }

    fn send(&self, msg: &ShardMsg) -> Result<()> {
        match lock_recover(&self.writer).as_mut() {
            Some(w) => write_frame(w, &encode_msg(msg)),
            None => bail!("shard worker connection already shut down"),
        }
    }

    /// Reap the child after the protocol says it is done (or kill it if
    /// it is not).  Closing our end of its stdin first lets a healthy
    /// worker observe EOF (= drain) and exit on its own.
    fn shutdown(&self, kill: bool) {
        drop(lock_recover(&self.writer).take());
        let mut child = lock_recover(&self.child);
        if kill {
            let _ = child.kill();
        }
        let _ = child.wait();
        if let Some(handle) = lock_recover(&self.reader).take() {
            let _ = handle.join();
        }
    }
}

/// Reader side of the stdio connection: routes replies to waiters.
fn demux_loop(
    mut stdout: ChildStdout,
    demux: Arc<Demux>,
    stats_tx: mpsc::Sender<ShardStatus>,
    drained_tx: mpsc::Sender<u64>,
) {
    loop {
        match read_frame(&mut stdout) {
            Ok(Some(frame)) => match decode_reply(&frame) {
                Ok(ShardReply::Response { response, status, spans }) => {
                    if let Some(status) = status {
                        *lock_recover(&demux.pushed) = Some((Instant::now(), status));
                    }
                    // worker-side spans stitch into this process's
                    // timeline for the request
                    trace::ingest_remote(spans);
                    let mut state = lock_recover(&demux.state);
                    state.responses.insert(response.id, response);
                    demux.arrived.notify_all();
                }
                Ok(ShardReply::Stats(status)) => {
                    let _ = stats_tx.send(status);
                }
                Ok(ShardReply::Drained { answered }) => {
                    let _ = drained_tx.send(answered);
                }
                Ok(ShardReply::Error { context }) => {
                    crate::log_warn!("shard worker error reply: {context}");
                }
                Ok(ShardReply::Ready { .. }) => {
                    crate::log_warn!("shard worker sent a duplicate ready frame");
                }
                Err(e) => {
                    // an undecodable reply means the framing is out of
                    // sync or the peer speaks something else — every
                    // later frame is suspect, and silently skipping one
                    // would strand its waiter forever.  Declare the
                    // connection dead so waiters fail loudly.
                    crate::log_warn!("undecodable shard reply, closing connection: {e:#}");
                    break;
                }
            },
            Ok(None) | Err(_) => break,
        }
    }
    lock_recover(&demux.state).dead = true;
    demux.arrived.notify_all();
}

impl ShardTransport for ProcessShard {
    fn kind(&self) -> &'static str {
        "process"
    }

    fn submit(
        &self,
        id: RequestId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
        resume: Option<SwarmSnapshot>,
    ) -> Result<()> {
        self.send(&ShardMsg::Submit {
            id,
            problem,
            priority,
            timeout,
            resume,
            trace: submit_trace_ctx(id),
        })
    }

    fn cancel(&self, id: RequestId) {
        // best-effort: a broken pipe means the worker is gone and every
        // waiter will fail over the dead flag anyway
        let _ = self.send(&ShardMsg::Cancel { id });
    }

    fn status(&self) -> Result<ShardStatus> {
        let control = lock_recover(&self.control);
        // a reply that arrived after an earlier call timed out would
        // otherwise answer *this* request and desync every later one
        while control.stats_rx.try_recv().is_ok() {}
        self.send(&ShardMsg::Stats)?;
        control
            .stats_rx
            .recv_timeout(self.tcfg.control_timeout)
            .context("shard worker did not answer a stats request")
    }

    fn try_response(&self, id: RequestId) -> Option<MatchResponse> {
        lock_recover(&self.demux.state).responses.remove(&id)
    }

    fn wait_response(&self, id: RequestId) -> Result<MatchResponse> {
        let mut state = lock_recover(&self.demux.state);
        loop {
            if let Some(resp) = state.responses.remove(&id) {
                return Ok(resp);
            }
            if state.dead {
                bail!("shard worker exited before answering request {id}");
            }
            state = self
                .demux
                .arrived
                .wait(state)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    fn drain(&self) -> Result<()> {
        let control = lock_recover(&self.control);
        self.send(&ShardMsg::Drain)?;
        let answered = control
            .drained_rx
            .recv_timeout(self.tcfg.control_timeout)
            .context("shard worker did not acknowledge the drain")?;
        drop(control);
        crate::log_debug!("shard worker drained after {answered} responses");
        self.shutdown(false);
        Ok(())
    }

    fn healthy(&self) -> bool {
        !lock_recover(&self.demux.state).dead && lock_recover(&self.writer).is_some()
    }

    fn lost(&self, id: RequestId) -> bool {
        // once the connection is dead, any reply not already demuxed
        // will never arrive
        let state = lock_recover(&self.demux.state);
        state.dead && !state.responses.contains_key(&id)
    }

    fn abort(&self) {
        self.shutdown(true);
    }

    fn take_pushed_status(&self) -> Option<(Instant, ShardStatus)> {
        lock_recover(&self.demux.pushed).take()
    }

    fn inject_frame_fault(&self, fault: FrameFault) -> Result<()> {
        let mut guard = lock_recover(&self.writer);
        let Some(w) = guard.as_mut() else {
            bail!("shard worker connection already shut down");
        };
        match fault {
            FrameFault::Garbage => {
                // well-framed, but the payload is not wire JSON — the
                // worker treats the connection as poisoned, finishes
                // pending episodes, and exits
                let payload = b"chaos: deliberately undecodable payload";
                let len = u32::try_from(payload.len()).context("garbage frame length")?;
                w.write_all(&len.to_be_bytes()).context("writing garbage frame length")?;
                w.write_all(payload).context("writing garbage frame payload")?;
                w.flush().context("flushing garbage frame")?;
            }
            FrameFault::Truncated => {
                // promise 64 payload bytes, deliver 4 and go silent:
                // the worker's reader blocks mid-frame and every later
                // frame lands *inside* the bogus payload — the wedged
                // connection whose control round-trips time out
                w.write_all(&64u32.to_be_bytes()).context("writing truncated frame length")?;
                w.write_all(b"cut!").context("writing truncated frame stub")?;
                w.flush().context("flushing truncated frame")?;
            }
        }
        Ok(())
    }
}

impl Drop for ProcessShard {
    fn drop(&mut self) {
        // Polite first (covers the normal cluster-drop path), forceful
        // if the worker is wedged.  Note the last-resort semantics: a
        // worker legitimately busy past CONTROL_TIMEOUT is killed
        // mid-episode here — callers who care about in-flight work must
        // consume their responses (or call `drain()`) before dropping.
        if self.drain().is_err() {
            self.shutdown(true);
        }
    }
}

/// Resolve the worker binary [`ProcessShard::spawn`] launches: the
/// [`WORKER_BIN_ENV`] override, this binary itself when it *is*
/// `immsched`, or an `immsched` binary sitting next to it (the cargo
/// target layout the bench binaries run from).
pub fn worker_binary() -> Result<PathBuf> {
    if let Ok(path) = std::env::var(WORKER_BIN_ENV) {
        return Ok(PathBuf::from(path));
    }
    let me = std::env::current_exe().context("resolving current executable")?;
    let stem = me.file_stem().and_then(|s| s.to_str()).unwrap_or("");
    if stem == "immsched" {
        return Ok(me);
    }
    let sibling = me.with_file_name(format!("immsched{}", std::env::consts::EXE_SUFFIX));
    if sibling.exists() {
        return Ok(sibling);
    }
    bail!(
        "cannot locate the `immsched` worker binary next to {} — build it \
         (`cargo build --release`) or set {WORKER_BIN_ENV}",
        me.display()
    )
}

// ---------------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------------

/// The `immsched shard-worker` loop: host one [`MatchService`] behind
/// the framed stdio protocol.  The first frame must be
/// [`ShardMsg::Hello`]; EOF on `input` is treated as a drain (finish
/// pending work, then exit) so a dying router never strands episodes
/// half-reported.
pub fn worker_serve<R, W>(input: R, output: W) -> Result<()>
where
    R: Read + Send + 'static,
    W: Write,
{
    worker_serve_with(input, output, TransportConfig::default())
}

/// The service's current load: answered to explicit `Stats` probes and
/// piggybacked on every `Response` so the router's status cache
/// refreshes on each reply.
fn service_status(svc: &MatchService) -> ShardStatus {
    let stats = svc.stats();
    let inventory = svc.in_flight_request();
    ShardStatus {
        queue_depth: stats.router.depth as usize,
        in_flight: inventory.map(|(_, p)| p),
        in_flight_id: inventory.map(|(id, _)| id),
        stats,
    }
}

/// [`worker_serve`] with explicit poll cadences (tests hosting the
/// worker loop in-process tune the sweep without multi-millisecond
/// waits).
pub fn worker_serve_with<R, W>(input: R, mut output: W, tcfg: TransportConfig) -> Result<()>
where
    R: Read + Send + 'static,
    W: Write,
{
    let mut input = input;
    let hello = read_frame(&mut input)?.context("EOF before the hello frame")?;
    let svc = match decode_msg(&hello) {
        Ok(ShardMsg::Hello { service, pso }) => MatchService::spawn_configured(service, pso)?,
        Ok(other) => {
            let reply = ShardReply::Error {
                context: format!("first frame must be hello, got {other:?}"),
            };
            write_frame(&mut output, &encode_reply(&reply))?;
            bail!("handshake failed: first frame was not hello");
        }
        Err(e) => {
            let reply = ShardReply::Error { context: format!("undecodable hello: {e:#}") };
            write_frame(&mut output, &encode_reply(&reply))?;
            return Err(e);
        }
    };
    write_frame(
        &mut output,
        &encode_reply(&ShardReply::Ready { schema: wire::WIRE_SCHEMA.to_string() }),
    )?;

    // decouple frame reading from episode completion: the reader thread
    // blocks on stdin while the main loop pumps finished episodes out
    let (tx, rx) = mpsc::channel::<ShardMsg>();
    let reader = std::thread::Builder::new().name("immsched-worker-reader".into()).spawn(
        move || {
            while let Ok(Some(frame)) = read_frame(&mut input) {
                let msg = match decode_msg(&frame) {
                    Ok(msg) => msg,
                    Err(e) => {
                        // out-of-sync framing poisons every later frame
                        // (and a dropped submit would strand its waiter)
                        // — treat it like EOF: drain pending and exit
                        crate::log_warn!("undecodable frame, closing connection: {e:#}");
                        break;
                    }
                };
                if tx.send(msg).is_err() {
                    break;
                }
            }
        },
    )?;

    let mut pending: Vec<(RequestId, MatchTicket)> = Vec::new();
    let mut answered: u64 = 0;
    let mut open = true;
    let mut draining = false;
    loop {
        // pump completions first so a drain observes them
        let mut finished: Vec<MatchResponse> = Vec::new();
        pending.retain(|(_, ticket)| match ticket.try_wait() {
            Some(resp) => {
                finished.push(resp);
                false
            }
            None => true,
        });
        for resp in finished {
            answered += 1;
            let spans = drain_worker_spans(resp.id);
            let reply = ShardReply::Response {
                response: resp,
                status: Some(service_status(&svc)),
                spans,
            };
            write_frame(&mut output, &encode_reply(&reply))?;
        }
        if pending.is_empty() {
            if draining {
                write_frame(&mut output, &encode_reply(&ShardReply::Drained { answered }))?;
                break;
            }
            if !open {
                break;
            }
        }
        let timeout = if pending.is_empty() { tcfg.idle_poll } else { tcfg.busy_poll };
        let msg = if open {
            match rx.recv_timeout(timeout) {
                Ok(msg) => Some(msg),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // router hung up: finish pending work, then exit
                    open = false;
                    None
                }
            }
        } else {
            std::thread::sleep(timeout);
            None
        };
        let Some(msg) = msg else { continue };
        match msg {
            ShardMsg::Hello { .. } => {
                let reply = ShardReply::Error { context: "duplicate hello".into() };
                write_frame(&mut output, &encode_reply(&reply))?;
            }
            ShardMsg::Submit { id, problem, priority, timeout, resume, trace: ctx } => {
                // a submit carrying a trace context asks this worker to
                // record spans and ship them back — the router's flag
                // crosses the boundary implicitly, no extra config verb
                if ctx.is_some() && !trace::enabled() {
                    trace::set_enabled(true);
                }
                let deadline = timeout.map(|t| svc.now() + t);
                // kept aside so a failed submission can still hand the
                // warm-start snapshot back (shedding must never destroy
                // persisted progress) — and so the waiter gets a real
                // response instead of hanging on an id-less error
                let backup = resume.clone();
                match svc.submit_with(
                    problem,
                    priority,
                    deadline,
                    SubmitOptions { id: Some(id), resume },
                ) {
                    Ok(ticket) => pending.push((id, ticket)),
                    Err(e) => {
                        crate::log_warn!("submit {id} failed on the worker: {e:#}");
                        let shed = MatchResponse {
                            id,
                            mappings: Vec::new(),
                            best_fitness: f32::NEG_INFINITY,
                            epochs_run: 0,
                            host_seconds: 0.0,
                            path: MatchPath::Shed,
                            resumed: false,
                            snapshot: backup,
                        };
                        answered += 1;
                        let spans = drain_worker_spans(id);
                        let reply = ShardReply::Response {
                            response: shed,
                            status: Some(service_status(&svc)),
                            spans,
                        };
                        write_frame(&mut output, &encode_reply(&reply))?;
                    }
                }
            }
            ShardMsg::Cancel { id } => {
                if let Some((_, ticket)) = pending.iter().find(|(pid, _)| *pid == id) {
                    ticket.cancel();
                }
            }
            ShardMsg::Stats => {
                let reply = ShardReply::Stats(service_status(&svc));
                write_frame(&mut output, &encode_reply(&reply))?;
            }
            ShardMsg::Drain => draining = true,
        }
    }
    output.flush().ok();
    drop(svc); // join the service thread before reporting exit
    // The reader thread may still be parked on a blocking stdin read
    // (the router keeps our stdin open until it reaps us) — detach it
    // instead of joining; process exit tears it down.
    drop(reader);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};

    fn chain_problem(n: usize, m: usize) -> MatchProblem {
        let qd = gen_chain(n, NodeKind::Compute);
        let gd = gen_chain(m, NodeKind::Universal);
        MatchProblem::from_dags(&qd, &gd)
    }

    #[test]
    fn in_process_transport_round_trip() {
        let shard = InProcessShard::spawn(
            ServiceConfig::default(),
            PsoConfig { seed: 3, ..Default::default() },
        )
        .unwrap();
        shard.submit(41, chain_problem(4, 8), Priority::Normal, None, None).unwrap();
        let resp = shard.wait_response(41).unwrap();
        assert_eq!(resp.id, 41);
        assert!(resp.matched());
        assert!(shard.try_response(41).is_none(), "a response is consumed exactly once");
        let status = shard.status().unwrap();
        assert_eq!(status.stats.controller.requests, 1);
        assert_eq!(shard.kind(), "in-process");
        // drain parity with the worker contract: settles, then rejects
        shard.drain().unwrap();
        let refused = shard.submit(42, chain_problem(4, 8), Priority::Normal, None, None);
        assert!(refused.is_err(), "a drained shard must reject new submissions");
    }

    #[test]
    fn in_process_cancel_reaches_a_queued_request() {
        let shard = InProcessShard::spawn(
            ServiceConfig::default(),
            PsoConfig { seed: 5, epochs: 50_000, ..Default::default() },
        )
        .unwrap();
        // a long-running episode keeps the controller busy…
        let mut q = crate::util::MatF::zeros(4, 4);
        q[(0, 1)] = 1.0;
        q[(0, 2)] = 1.0;
        q[(0, 3)] = 1.0;
        let star = MatchProblem::from_dense(
            &crate::util::MatF::full(4, 8, 1.0),
            &q,
            &gen_chain(8, NodeKind::Universal).adjacency(),
        );
        shard.submit(1, star, Priority::Normal, None, None).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        shard.cancel(1);
        let resp = shard.wait_response(1).unwrap();
        assert_eq!(resp.path, crate::coordinator::MatchPath::Cancelled);
    }

    #[test]
    fn worker_binary_resolves_or_errors_helpfully() {
        // under `cargo test` the current exe is a test binary, so the
        // resolver either finds a sibling immsched or explains how to
        // get one — it must never return a path that does not exist
        match worker_binary() {
            Ok(path) => assert!(path.exists(), "resolved worker {} missing", path.display()),
            Err(e) => assert!(e.to_string().contains("worker binary"), "{e:#}"),
        }
    }
}
