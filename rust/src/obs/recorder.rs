//! `obs::recorder`: a bounded ring-buffer flight recorder.
//!
//! The supervision and chaos layers feed it structured events
//! (heartbeat probe failures, dead-shard declarations, replays,
//! shed-at-floor decisions, injected faults).  When something goes
//! wrong — a shard is declared dead, admission hits the capacity
//! floor, a chaos fault fires — the fleet asks for a [`dump`]: one
//! versioned `immsched.obs/v1` JSON document carrying the dump reason,
//! the recent event ring, a full metrics snapshot, and every stitched
//! request timeline.  That document is what a postmortem reads; the
//! README's "Observability" section walks through one.
//!
//! Like the rest of the plane, the recorder is bounded (old events
//! fall off the ring; the drop count is part of the dump) and off by
//! default.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use once_cell::sync::Lazy;

use crate::util::json::{hex_u64, Json};

use super::{clock, metrics, obs_lock, trace};

/// Schema tag of a flight-recorder dump document.
pub const OBS_DUMP_SCHEMA: &str = "immsched.obs/v1";

/// Default ring capacity (events retained; older ones fall off).
const DEFAULT_RING_CAP: usize = 1 << 12;

/// One recorded incident event: a kind tag plus ordered key=value
/// fields, stamped with a sequence number and an `obs::clock` time.
#[derive(Clone, Debug)]
pub struct RecorderEvent {
    /// Monotonic per-recorder sequence number (survives ring
    /// eviction, so gaps in a dump reveal how much history was lost).
    pub seq: u64,
    pub at_nanos: u64,
    /// Event kind, e.g. `"shard-dead"`, `"replay"`, `"shed-floor"`,
    /// `"chaos-fault"`, `"redial"`.
    pub kind: String,
    /// Ordered key=value detail fields.
    pub fields: Vec<(String, String)>,
}

impl RecorderEvent {
    fn to_json(&self) -> Json {
        let mut obj = vec![
            ("seq", hex_u64(self.seq)),
            ("at_ns", hex_u64(self.at_nanos)),
            ("kind", Json::from(self.kind.as_str())),
        ];
        let mut fields = Vec::with_capacity(self.fields.len());
        for (k, v) in &self.fields {
            fields.push((k.clone(), Json::from(v.as_str())));
        }
        obj.push(("fields", Json::Obj(fields)));
        Json::obj(obj)
    }
}

/// The bounded ring of recent incident events.
pub struct FlightRecorder {
    ring: Mutex<VecDeque<RecorderEvent>>,
    cap: usize,
    next_seq: AtomicU64,
    evicted: AtomicU64,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_RING_CAP)
    }
}

impl FlightRecorder {
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            ring: Mutex::new(VecDeque::new()),
            cap: cap.max(1),
            next_seq: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
        }
    }

    /// Append one event, evicting the oldest past capacity.
    pub fn record(&self, kind: &str, fields: Vec<(String, String)>) {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let ev = RecorderEvent { seq, at_nanos: clock::now_nanos(), kind: kind.to_string(), fields };
        let mut ring = obs_lock(&self.ring);
        if ring.len() >= self.cap {
            ring.pop_front();
            self.evicted.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(ev);
    }

    /// Events currently retained.
    pub fn len(&self) -> usize {
        obs_lock(&self.ring).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events that fell off the ring.
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// Snapshot of the retained events, oldest first.
    pub fn events(&self) -> Vec<RecorderEvent> {
        obs_lock(&self.ring).iter().cloned().collect()
    }

    /// Forget everything (tests; paired bench runs).
    pub fn clear(&self) {
        obs_lock(&self.ring).clear();
        self.next_seq.store(0, Ordering::Relaxed);
        self.evicted.store(0, Ordering::Relaxed);
    }

    /// Build one `immsched.obs/v1` dump document: the reason, this
    /// ring, a metrics snapshot, and every request timeline.
    pub fn dump(&self, reason: &str) -> Json {
        Json::obj(vec![
            ("schema", Json::from(OBS_DUMP_SCHEMA)),
            ("reason", Json::from(reason)),
            ("at_ns", hex_u64(clock::now_nanos())),
            ("evicted", hex_u64(self.evicted())),
            (
                "events",
                Json::Arr(obs_lock(&self.ring).iter().map(RecorderEvent::to_json).collect()),
            ),
            ("metrics", metrics::registry().snapshot()),
            ("timelines", trace::tracer().timelines_json()),
        ])
    }
}

/// The process flight recorder.
static GLOBAL: Lazy<FlightRecorder> = Lazy::new(FlightRecorder::default);

/// Gate for [`record`]: disabled recording costs one relaxed load.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Where [`dump_to_disk`] writes (set by `--obs-out`); empty = nowhere.
static DUMP_PATH: Lazy<Mutex<Option<PathBuf>>> = Lazy::new(|| Mutex::new(None));

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The process flight recorder (dump tooling and tests).
pub fn recorder() -> &'static FlightRecorder {
    &GLOBAL
}

/// Record an incident event (when the recorder is enabled).  Fields
/// are `(key, value)` pairs; build them lazily at the call site with
/// `vec![...]` only after checking nothing — this function gates.
pub fn record(kind: &str, fields: Vec<(String, String)>) {
    if enabled() {
        GLOBAL.record(kind, fields);
    }
}

/// Set (or clear) the on-disk dump destination.
pub fn set_dump_path(path: Option<PathBuf>) {
    *obs_lock(&DUMP_PATH) = path;
}

/// The configured dump destination, if any.
pub fn dump_path() -> Option<PathBuf> {
    obs_lock(&DUMP_PATH).clone()
}

/// Write a dump document for `reason` to the configured path (latest
/// dump wins — one file, always the most recent incident).  No-op
/// without a path; IO failures are logged, never fatal: telemetry
/// must not take the serving path down.
pub fn dump_to_disk(reason: &str) {
    let Some(path) = dump_path() else { return };
    write_dump(&path, reason);
}

fn write_dump(path: &Path, reason: &str) {
    let doc = GLOBAL.dump(reason).render();
    if let Err(err) = std::fs::write(path, doc) {
        crate::log_warn!("obs: failed to write dump to {}: {err}", path.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_evicts_oldest_and_counts() {
        let r = FlightRecorder::with_capacity(2);
        r.record("a", vec![]);
        r.record("b", vec![("shard".into(), "1".into())]);
        r.record("c", vec![]);
        let events = r.events();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, "b");
        assert_eq!(events[1].kind, "c");
        assert_eq!(events[1].seq, 2);
        assert_eq!(r.evicted(), 1);
        r.clear();
        assert!(r.is_empty());
    }

    #[test]
    fn dump_is_versioned_and_parses() {
        let r = FlightRecorder::with_capacity(8);
        r.record("shard-dead", vec![("shard".into(), "0".into()), ("why".into(), "probe".into())]);
        let doc = r.dump("test-incident").render();
        let back = Json::parse(&doc).expect("valid JSON");
        assert_eq!(back.get("schema").and_then(Json::as_str), Some(OBS_DUMP_SCHEMA));
        assert_eq!(back.get("reason").and_then(Json::as_str), Some("test-incident"));
        let events = back.get("events").and_then(Json::as_array).expect("events");
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("kind").and_then(Json::as_str), Some("shard-dead"));
        assert_eq!(
            events[0].get("fields").and_then(|f| f.get("shard")).and_then(Json::as_str),
            Some("0")
        );
        assert!(back.get("metrics").is_some());
        assert!(back.get("timelines").is_some());
    }
}
