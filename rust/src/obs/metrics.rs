//! `obs::metrics`: a process-wide registry of named counters, gauges
//! and fixed-bucket histograms.
//!
//! Design constraints (the same ones the matcher hot path lives
//! under):
//!
//! * **Allocation-free hot path.**  A metric is registered once (one
//!   lock + one allocation) and returns a cheap [`Counter`] /
//!   [`Gauge`] / [`Histogram`] handle that is a bare `Arc<AtomicU64>`
//!   op to touch.  Library call sites keep handles in `Lazy` statics
//!   (see [`well`]), so steady-state instrumentation is one relaxed
//!   atomic RMW.
//! * **Deterministic iteration.**  The registry is a `BTreeMap`, so a
//!   snapshot always lists metrics in name order — dumps diff cleanly
//!   and the determinism lint scope covers this file.
//! * **Namespaced names.**  `service.*` (per-shard admission/engine
//!   counters), `cluster.*` (routing, failover, resume),
//!   `net.*` (socket links, redials), `matcher.*` (episode work).
//!   The pre-existing stats structs publish into these namespaces as
//!   *views* via the `publish_*` helpers — one registry, one dump
//!   format, no parallel bookkeeping to drift.
//!
//! The global registry records regardless of the enabled flag (the
//! atomics are the cheap part); the flag gates the *publish* helpers
//! and is what `--obs-out` flips.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use once_cell::sync::Lazy;

use crate::util::json::Json;

use super::obs_lock;

/// What a registered metric is (drives rendering and dump layout).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

/// A monotone event counter handle.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite the value — how a stats-struct *view* publishes its
    /// externally accumulated total into the registry.
    pub fn store(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A point-in-time level (queue depths, live shard counts).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Upper bounds (inclusive) of the fixed latency buckets, in
/// microseconds: eight powers of four from 1µs to ~16s, plus the
/// implicit overflow bucket.  One fixed shape for every histogram
/// keeps `observe` allocation-free and dumps comparable.
pub const BUCKET_BOUNDS_US: [u64; 8] = [1, 4, 16, 64, 256, 1_024, 16_384, 262_144];

/// A fixed-bucket histogram of microsecond durations.
#[derive(Debug)]
pub struct HistogramCore {
    buckets: [AtomicU64; BUCKET_BOUNDS_US.len() + 1],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for HistogramCore {
    fn default() -> Self {
        Self {
            buckets: Default::default(),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

/// A histogram handle (shared core behind an `Arc`).
#[derive(Clone, Debug, Default)]
pub struct Histogram(Arc<HistogramCore>);

impl Histogram {
    /// Record one duration (microseconds).  Allocation-free: a linear
    /// probe over eight fixed bounds plus three relaxed RMWs.
    pub fn observe_us(&self, us: u64) {
        let mut idx = BUCKET_BOUNDS_US.len();
        for (i, bound) in BUCKET_BOUNDS_US.iter().enumerate() {
            if us <= *bound {
                idx = i;
                break;
            }
        }
        if let Some(slot) = self.0.buckets.get(idx) {
            slot.fetch_add(1, Ordering::Relaxed);
        }
        self.0.sum_us.fetch_add(us, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.0.sum_us.load(Ordering::Relaxed)
    }

    /// Mean observed duration in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us() as f64 / n as f64
        }
    }

    fn bucket_counts(&self) -> Vec<u64> {
        self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

impl Metric {
    /// The registered kind (exposed for dump tooling / mismatch logs).
    pub(crate) fn kind(&self) -> MetricKind {
        match self {
            Metric::Counter(_) => MetricKind::Counter,
            Metric::Gauge(_) => MetricKind::Gauge,
            Metric::Histogram(_) => MetricKind::Histogram,
        }
    }
}

/// The registry: name → metric, ordered.  Registration is idempotent
/// (same name + same kind returns the existing handle), so every layer
/// can `register` lazily without coordination.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or fetch) a counter.  A name already registered with
    /// a different kind yields a fresh unregistered handle — the
    /// mismatch is a bug, but telemetry must never panic a server.
    pub fn counter(&self, name: &str) -> Counter {
        let mut map = obs_lock(&self.metrics);
        match map.entry(name.to_string()).or_insert_with(|| Metric::Counter(Counter::default()))
        {
            Metric::Counter(c) => c.clone(),
            _ => {
                crate::log_warn!("metric {name:?} re-registered with a different kind");
                Counter::default()
            }
        }
    }

    /// Register (or fetch) a gauge.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut map = obs_lock(&self.metrics);
        match map.entry(name.to_string()).or_insert_with(|| Metric::Gauge(Gauge::default())) {
            Metric::Gauge(g) => g.clone(),
            _ => {
                crate::log_warn!("metric {name:?} re-registered with a different kind");
                Gauge::default()
            }
        }
    }

    /// Register (or fetch) a histogram.
    pub fn histogram(&self, name: &str) -> Histogram {
        let mut map = obs_lock(&self.metrics);
        match map
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Histogram::default()))
        {
            Metric::Histogram(h) => h.clone(),
            _ => {
                crate::log_warn!("metric {name:?} re-registered with a different kind");
                Histogram::default()
            }
        }
    }

    /// The kind `name` was registered as, if it exists.
    pub fn kind_of(&self, name: &str) -> Option<MetricKind> {
        obs_lock(&self.metrics).get(name).map(Metric::kind)
    }

    /// Number of registered metrics.
    pub fn len(&self) -> usize {
        obs_lock(&self.metrics).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Deterministic JSON snapshot (name-ordered), the `metrics`
    /// section of an `immsched.obs/v1` dump.
    pub fn snapshot(&self) -> Json {
        let map = obs_lock(&self.metrics);
        let mut fields = Vec::with_capacity(map.len());
        for (name, metric) in map.iter() {
            let value = match metric {
                Metric::Counter(c) => Json::obj(vec![
                    ("kind", Json::from("counter")),
                    ("value", Json::from(c.get())),
                ]),
                Metric::Gauge(g) => {
                    let v = g.get();
                    Json::obj(vec![("kind", Json::from("gauge")), ("value", Json::Num(v as f64))])
                }
                Metric::Histogram(h) => Json::obj(vec![
                    ("kind", Json::from("histogram")),
                    ("count", Json::from(h.count())),
                    ("sum_us", Json::from(h.sum_us())),
                    ("mean_us", Json::from(h.mean_us())),
                    (
                        "bounds_us",
                        Json::Arr(BUCKET_BOUNDS_US.iter().map(|b| Json::from(*b)).collect()),
                    ),
                    (
                        "buckets",
                        Json::Arr(h.bucket_counts().into_iter().map(Json::from).collect()),
                    ),
                ]),
            };
            fields.push((name.clone(), value));
        }
        Json::Obj(fields)
    }

    /// Plain-text rendering, name-ordered — the `immsched metrics`
    /// one-shot output.
    pub fn render_text(&self) -> String {
        let map = obs_lock(&self.metrics);
        let mut out = String::new();
        let width = map.keys().map(String::len).max().unwrap_or(0);
        for (name, metric) in map.iter() {
            let line = match metric {
                Metric::Counter(c) => format!("{name:<width$}  counter    {}", c.get()),
                Metric::Gauge(g) => format!("{name:<width$}  gauge      {}", g.get()),
                Metric::Histogram(h) => format!(
                    "{name:<width$}  histogram  count={} mean={:.1}us",
                    h.count(),
                    h.mean_us()
                ),
            };
            out.push_str(&line);
            out.push('\n');
        }
        out
    }
}

/// The process-wide registry.
static GLOBAL: Lazy<Registry> = Lazy::new(Registry::new);

/// Whether the publish helpers are live (`--obs-out` flips this).
static ENABLED: AtomicBool = AtomicBool::new(false);

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The process-wide registry (register handles against this).
pub fn registry() -> &'static Registry {
    &GLOBAL
}

/// Well-known hot-path handles, registered once per process.  Call
/// sites go through these `Lazy` statics so instrumenting a path costs
/// one relaxed atomic op, never a name lookup.
pub mod well {
    use super::{registry, Counter, Histogram, Lazy};

    macro_rules! well_counter {
        ($(#[$doc:meta])* $ident:ident, $name:literal) => {
            $(#[$doc])*
            pub static $ident: Lazy<Counter> = Lazy::new(|| registry().counter($name));
        };
    }

    well_counter!(
        /// Requests admitted by a shard's admission router.
        SERVICE_ADMITTED, "service.admitted");
    well_counter!(
        /// Requests shed at admission (expired or over capacity).
        SERVICE_SHED, "service.shed");
    well_counter!(
        /// Episodes preempted/cancelled at an epoch barrier.
        SERVICE_PREEMPTED, "service.preempted");
    well_counter!(
        /// Episodes that warm-started from a persisted snapshot.
        SERVICE_RESUMED, "service.resumed");
    well_counter!(
        /// Requests routed by the cluster front router.
        CLUSTER_ROUTED, "cluster.routed");
    well_counter!(
        /// Terminal outcomes recorded by the open-loop driver.
        CLUSTER_TERMINAL, "cluster.terminal");
    well_counter!(
        /// In-flight requests replayed off a dead shard.
        CLUSTER_REPLAYS, "cluster.failover.replays");
    well_counter!(
        /// Shards declared dead by the supervision heartbeat.
        CLUSTER_SHARDS_FAILED, "cluster.failover.shards_failed");
    well_counter!(
        /// Requests shed at the capacity floor.
        CLUSTER_SHED_AT_FLOOR, "cluster.failover.shed_at_floor");
    well_counter!(
        /// Severed socket links redialed.
        NET_REDIALS, "net.redials");
    well_counter!(
        /// In-flight submits replayed over a healed link.
        NET_RESUBMITS, "net.resubmits");
    well_counter!(
        /// Chaos faults injected (all kinds).
        CHAOS_FAULTS, "net.chaos.faults");
    well_counter!(
        /// PSO epochs executed across all episodes.
        MATCHER_EPOCHS, "matcher.epochs");

    /// End-to-end request latency as observed by the driver.
    pub static CLUSTER_LATENCY: Lazy<Histogram> =
        Lazy::new(|| registry().histogram("cluster.request_latency_us"));
}

// ---------------------------------------------------------------------------
// stats-struct views: publish the pre-existing aggregate structs into
// the registry under their namespaces
// ---------------------------------------------------------------------------

/// Publish a [`crate::coordinator::ServiceStats`] snapshot for one
/// shard (per-shard gauge/counter names under `service.shard<N>.*`).
pub fn publish_service(shard: usize, stats: &crate::coordinator::ServiceStats) {
    if !enabled() {
        return;
    }
    let r = registry();
    let base = format!("service.shard{shard}");
    r.counter(&format!("{base}.requests")).store(stats.controller.requests);
    r.counter(&format!("{base}.matched")).store(stats.controller.matched);
    r.counter(&format!("{base}.cancelled")).store(stats.controller.cancelled);
    r.counter(&format!("{base}.resumed")).store(stats.controller.resumed);
    r.counter(&format!("{base}.rejected")).store(stats.controller.rejected);
    r.counter(&format!("{base}.epochs")).store(stats.controller.epochs_total);
    r.counter(&format!("{base}.admitted")).store(stats.router.admitted);
    r.counter(&format!("{base}.shed_expired")).store(stats.router.shed_expired);
    r.counter(&format!("{base}.shed_capacity")).store(stats.router.shed_capacity);
    let depth = i64::try_from(stats.router.depth).unwrap_or(i64::MAX);
    r.gauge(&format!("{base}.queue_depth")).set(depth);
}

/// Publish a [`crate::cluster::FailoverStats`] snapshot
/// (`cluster.failover.*`).
pub fn publish_failover(stats: &crate::cluster::FailoverStats) {
    if !enabled() {
        return;
    }
    let r = registry();
    r.counter("cluster.failover.probes").store(stats.probes);
    r.counter("cluster.failover.probe_failures").store(stats.probe_failures);
    r.counter("cluster.failover.shards_failed").store(stats.shards_failed);
    r.counter("cluster.failover.replays").store(stats.replays);
    r.counter("cluster.failover.respawns").store(stats.respawns);
    r.counter("cluster.failover.shed_at_floor").store(stats.shed_at_floor);
}

/// Publish a [`crate::cluster::net::ReconnectStats`] snapshot for one
/// socket link (`net.*`).
pub fn publish_reconnect(stats: &crate::cluster::net::ReconnectStats) {
    if !enabled() {
        return;
    }
    let r = registry();
    r.counter("net.redials").store(stats.redials);
    r.counter("net.resubmits").store(stats.resubmits);
}

/// Publish a [`crate::cluster::ChaosStats`] snapshot (`net.chaos.*`).
pub fn publish_chaos(stats: &crate::cluster::ChaosStats) {
    if !enabled() {
        return;
    }
    let r = registry();
    r.counter("net.chaos.delays").store(stats.delays);
    r.counter("net.chaos.dropped_replies").store(stats.dropped_replies);
    r.counter("net.chaos.garbage_frames").store(stats.garbage_frames);
    r.counter("net.chaos.truncated_frames").store(stats.truncated_frames);
    r.counter("net.chaos.kills").store(stats.kills);
    r.counter("net.chaos.unsupported").store(stats.unsupported);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms_round_trip() {
        let r = Registry::new();
        let c = r.counter("service.admitted");
        c.inc();
        c.add(2);
        assert_eq!(c.get(), 3);
        // idempotent registration returns the same underlying cell
        assert_eq!(r.counter("service.admitted").get(), 3);

        let g = r.gauge("service.queue_depth");
        g.set(7);
        g.add(-2);
        assert_eq!(g.get(), 5);

        let h = r.histogram("cluster.latency_us");
        h.observe_us(3);
        h.observe_us(100);
        h.observe_us(10_000_000); // overflow bucket
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum_us(), 10_000_103);
        assert_eq!(r.len(), 3);
        assert_eq!(r.kind_of("service.admitted"), Some(MetricKind::Counter));
        assert_eq!(r.kind_of("service.queue_depth"), Some(MetricKind::Gauge));
        assert_eq!(r.kind_of("cluster.latency_us"), Some(MetricKind::Histogram));
        assert_eq!(r.kind_of("absent"), None);
    }

    #[test]
    fn snapshot_is_name_ordered_and_valid_json() {
        let r = Registry::new();
        r.counter("z.last").inc();
        r.counter("a.first").inc();
        r.gauge("m.mid").set(-4);
        let snap = snap_names(&r);
        assert_eq!(snap, vec!["a.first", "m.mid", "z.last"]);
        let text = r.snapshot().render();
        let back = Json::parse(&text).expect("snapshot renders as valid JSON");
        assert_eq!(
            back.get("m.mid").and_then(|m| m.get("value")).and_then(Json::as_f64),
            Some(-4.0)
        );
        assert!(r.render_text().lines().count() == 3);
    }

    fn snap_names(r: &Registry) -> Vec<String> {
        match r.snapshot() {
            Json::Obj(fields) => fields.into_iter().map(|(k, _)| k).collect(),
            _ => Vec::new(),
        }
    }

    #[test]
    fn kind_mismatch_degrades_instead_of_panicking() {
        crate::util::logging::disable();
        let r = Registry::new();
        r.counter("dual");
        let g = r.gauge("dual");
        g.set(9);
        assert_eq!(g.get(), 9, "the orphan handle still works");
        assert_eq!(r.counter("dual").get(), 0, "the registered counter is untouched");
        crate::util::logging::set_max_level(crate::util::logging::Level::Warn);
    }

    #[test]
    fn histogram_buckets_partition_the_range() {
        let h = Histogram::default();
        for bound in BUCKET_BOUNDS_US {
            h.observe_us(bound);
        }
        h.observe_us(BUCKET_BOUNDS_US[BUCKET_BOUNDS_US.len() - 1] + 1);
        let counts = h.bucket_counts();
        assert_eq!(counts.len(), BUCKET_BOUNDS_US.len() + 1);
        assert!(counts.iter().all(|&c| c == 1), "{counts:?}");
    }
}
