//! `obs/`: the unified observability plane — metrics, traces, and a
//! failure flight recorder, wired through every serving layer.
//!
//! The paper's global controller schedules from *observed* runtime
//! state; the ROADMAP's next directions (predictive preemption,
//! sparsity-aware routing) both need a measurement plane the ad-hoc
//! stats structs could not provide.  This module is that plane, built
//! dependency-free in the `util::json` idiom:
//!
//! * [`metrics`] — a process-wide registry of named counters, gauges
//!   and fixed-bucket histograms.  Handles are pre-registered atomics,
//!   so the hot path is an `AtomicU64` op with no allocation and no
//!   lock; iteration is deterministic (ordered maps) per the lint
//!   rules.  The five pre-existing stats structs (`ControllerStats`,
//!   `ServiceStats`, `FailoverStats`, `ReconnectStats`, `ChaosStats`)
//!   publish into it as namespaced views (`service.*`, `cluster.*`,
//!   `net.*`, `matcher.*`).
//! * [`trace`] — per-request span timelines covering the full
//!   lifecycle (submit → admit/shed → route → epoch slices →
//!   preempt/snapshot/resume → replay/redial → terminal outcome).  A
//!   [`trace::TraceCtx`] travels in the wire protocol (schema v4), so
//!   worker-side spans ride back on replies and a multi-host request
//!   stitches into one timeline.
//! * [`recorder`] — a bounded ring buffer of recent structured events
//!   that `SupervisedFleet` dumps as versioned `immsched.obs/v1` JSON
//!   on dead-shard declaration, shed-at-floor, and chaos-induced
//!   faults, making every failover postmortem-able.
//! * [`clock`] — the *only* file in this subtree allowed to read the
//!   host clock (`immsched-lint` rule 7, `obs-clock-discipline`).
//!   Everything above stamps through [`clock::now_nanos`], and tests
//!   flip it to a logical clock for deterministic timelines.
//!
//! Everything is off by default and costs one relaxed atomic load per
//! probe when disabled — the `obs_overhead` block in
//! `BENCH_cluster.json` tracks the enabled cost as a measured number.

pub mod clock;
pub mod metrics;
pub mod recorder;
pub mod trace;

use std::sync::{Mutex, MutexGuard};

pub use metrics::{registry, MetricKind, Registry};
pub use recorder::{recorder, FlightRecorder, OBS_DUMP_SCHEMA};
pub use trace::{tracer, SpanKind, TraceCtx, TraceEvent, Tracer};

/// Enable the whole plane (metrics + tracing + recorder) in one call —
/// what `--obs-out` and `immsched metrics` flip on.
pub fn enable_all() {
    metrics::set_enabled(true);
    trace::set_enabled(true);
    recorder::set_enabled(true);
}

/// Disable the whole plane (the default state).
pub fn disable_all() {
    metrics::set_enabled(false);
    trace::set_enabled(false);
    recorder::set_enabled(false);
}

/// Poison-recovering lock acquisition, local to the observability
/// plane: a panicked writer elsewhere must never take telemetry down
/// with it (and the no-panic lint scope covers this subtree).
pub(crate) fn obs_lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enable_toggles_every_layer() {
        enable_all();
        assert!(metrics::enabled());
        assert!(trace::enabled());
        assert!(recorder::enabled());
        disable_all();
        assert!(!metrics::enabled());
        assert!(!trace::enabled());
        assert!(!recorder::enabled());
    }
}
