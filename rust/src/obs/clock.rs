//! The observability clock seam — the **only** place in `obs/` allowed
//! to touch the host clock (`immsched-lint` rule 7,
//! `obs-clock-discipline`; this file sits on the wallclock boundary).
//!
//! Spans and recorder events stamp through [`now_nanos`].  In the
//! default mode that is nanoseconds since the first observability
//! probe of the process (monotonic, `Instant`-backed — never the
//! system calendar, so a stamped timeline is immune to NTP steps).
//! Deterministic tests flip to the *logical* mode, where every read
//! ticks a counter: timestamps become a replayable total order, so two
//! same-seed runs produce bit-identical dumps.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

/// Monotonic anchor: the first clock read of the process.
static START: Lazy<Instant> = Lazy::new(Instant::now);

/// When set, [`now_nanos`] serves logical ticks instead of wall time.
static LOGICAL: AtomicBool = AtomicBool::new(false);

/// The logical tick counter (each read is one tick, so every stamp in
/// a single-threaded replay is distinct and strictly increasing).
static TICKS: AtomicU64 = AtomicU64::new(0);

/// Current observability timestamp in nanoseconds.
///
/// Wall mode: monotonic nanos since process anchor (saturating at
/// `u64::MAX` — ~584 years of uptime).  Logical mode: the next tick.
pub fn now_nanos() -> u64 {
    if LOGICAL.load(Ordering::Relaxed) {
        TICKS.fetch_add(1, Ordering::Relaxed).saturating_add(1)
    } else {
        let nanos = START.elapsed().as_nanos();
        u64::try_from(nanos).unwrap_or(u64::MAX)
    }
}

/// Switch to the deterministic logical clock and reset it to zero
/// (tests that compare dumps or timelines byte-for-byte).
pub fn use_logical() {
    TICKS.store(0, Ordering::Relaxed);
    LOGICAL.store(true, Ordering::Relaxed);
}

/// Switch back to the monotonic wall clock (the default).
pub fn use_wall() {
    LOGICAL.store(false, Ordering::Relaxed);
}

/// Whether the logical clock is active.
pub fn is_logical() -> bool {
    LOGICAL.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logical_ticks_are_strictly_increasing() {
        // tolerant of concurrent unit tests also reading the clock:
        // assert strict monotonic progression, not absolute values
        use_logical();
        assert!(is_logical());
        let a = now_nanos();
        let b = now_nanos();
        let c = now_nanos();
        assert!(a >= 1 && b > a && c > b, "ticks must strictly increase: {a} {b} {c}");
        use_wall();
        assert!(!is_logical());
    }

    #[test]
    fn wall_mode_is_monotonic() {
        use_wall();
        let a = now_nanos();
        let b = now_nanos();
        assert!(b >= a);
    }
}
