//! `obs::trace`: per-request span timelines across hosts.
//!
//! Every layer a request passes through appends one [`TraceEvent`] to
//! the process tracer: submit → admit/shed → route → epoch slices →
//! preempt/snapshot/resume → replay/redial → terminal outcome.  The
//! request id is the correlation key (cluster ids are globally unique,
//! and the wire protocol echoes them on every reply).
//!
//! Across a process or host boundary a [`TraceCtx`] travels inside the
//! `submit` frame (wire schema v4) and the worker's own spans ride
//! back on the `response` frame, where the router ingests them with
//! the `remote` flag set — one request, one stitched timeline, no
//! clock agreement required (worker stamps are worker-local; ordering
//! within a side is what matters, and the slice/admit structure is
//! what postmortems read).
//!
//! Terminal accounting is the *driver's* job: a preempted slice is not
//! the end of a request's life (the driver resubmits it), so only
//! [`terminal`] marks an event terminal, and the conservation property
//! (`tests/obs.rs`) is "every submitted id has exactly one terminal
//! event".
//!
//! All stamps go through [`super::clock`] (lint rule 7 bans any other
//! clock in this subtree).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use once_cell::sync::Lazy;

use crate::util::json::{hex_u64, Json};

use super::{clock, obs_lock};

/// One step of a request's lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// The request entered a service's submission path.
    Submit,
    /// Admission accepted it into the queue.
    Admit,
    /// Admission (or the fleet's capacity floor) shed it.
    Shed,
    /// The cluster router picked a shard.
    Route,
    /// One epoch slice executed (detail carries the epoch count).
    Slice,
    /// The episode was interrupted at an epoch barrier.
    Preempt,
    /// A warm-start snapshot was captured with the response.
    Snapshot,
    /// The episode warm-started from a persisted snapshot.
    Resume,
    /// Supervision replayed the request off a dead shard.
    Replay,
    /// A severed socket link was redialed.
    Redial,
    /// An in-flight submit was resubmitted over a healed link.
    Resubmit,
    /// A chaos fault was injected into this request's submission.
    Fault,
    /// Terminal: answered with a match / exhausted budget.
    Done,
    /// Terminal: the request ended cancelled.
    Cancelled,
    /// Terminal: the request could not be served (transport error).
    Failed,
}

impl SpanKind {
    /// Stable wire / dump name.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Submit => "submit",
            SpanKind::Admit => "admit",
            SpanKind::Shed => "shed",
            SpanKind::Route => "route",
            SpanKind::Slice => "slice",
            SpanKind::Preempt => "preempt",
            SpanKind::Snapshot => "snapshot",
            SpanKind::Resume => "resume",
            SpanKind::Replay => "replay",
            SpanKind::Redial => "redial",
            SpanKind::Resubmit => "resubmit",
            SpanKind::Fault => "fault",
            SpanKind::Done => "done",
            SpanKind::Cancelled => "cancelled",
            SpanKind::Failed => "failed",
        }
    }

    /// Inverse of [`Self::name`] (wire decode).
    pub fn from_name(name: &str) -> Option<SpanKind> {
        Some(match name {
            "submit" => SpanKind::Submit,
            "admit" => SpanKind::Admit,
            "shed" => SpanKind::Shed,
            "route" => SpanKind::Route,
            "slice" => SpanKind::Slice,
            "preempt" => SpanKind::Preempt,
            "snapshot" => SpanKind::Snapshot,
            "resume" => SpanKind::Resume,
            "replay" => SpanKind::Replay,
            "redial" => SpanKind::Redial,
            "resubmit" => SpanKind::Resubmit,
            "fault" => SpanKind::Fault,
            "done" => SpanKind::Done,
            "cancelled" => SpanKind::Cancelled,
            "failed" => SpanKind::Failed,
            _ => return None,
        })
    }
}

/// The trace context that crosses the wire inside a `submit` frame.
/// Both words are full u64s and travel as 16-digit hex, so the context
/// round-trips bit-exactly (ids and random trace words may exceed
/// 2^53).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCtx {
    /// The timeline this request belongs to (the cluster uses the
    /// globally unique request id).
    pub trace_id: u64,
    /// The span that caused this hop (0 = root).
    pub parent: u64,
}

/// One recorded lifecycle event.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Request id (the correlation key).
    pub id: u64,
    pub kind: SpanKind,
    /// Stamp from [`clock::now_nanos`] — monotonic process-local
    /// nanos, or logical ticks under the deterministic clock.
    pub at_nanos: u64,
    /// Exactly one terminal event per request (driver-recorded).
    pub terminal: bool,
    /// Ingested from a worker reply rather than recorded locally.
    pub remote: bool,
    /// Free-form `key=value` detail (shard, epoch counts, reasons).
    pub detail: String,
}

impl TraceEvent {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::from(self.kind.name())),
            ("at_ns", hex_u64(self.at_nanos)),
            ("terminal", Json::from(self.terminal)),
            ("remote", Json::from(self.remote)),
            ("detail", Json::from(self.detail.as_str())),
        ])
    }
}

/// A bounded event store: per-request timelines in insertion order.
pub struct Tracer {
    events: Mutex<Vec<TraceEvent>>,
    cap: usize,
    dropped: AtomicU64,
}

/// Default capacity of the process tracer (events, not requests).
const DEFAULT_TRACER_CAP: usize = 1 << 16;

impl Default for Tracer {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_TRACER_CAP)
    }
}

impl Tracer {
    pub fn with_capacity(cap: usize) -> Self {
        Self { events: Mutex::new(Vec::new()), cap: cap.max(1), dropped: AtomicU64::new(0) }
    }

    /// Append one event (dropped, and counted, past the capacity cap —
    /// telemetry must never grow without bound in a long-lived server).
    pub fn push(&self, ev: TraceEvent) {
        let mut events = obs_lock(&self.events);
        if events.len() >= self.cap {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        events.push(ev);
    }

    /// Record a local event now.
    pub fn record(&self, id: u64, kind: SpanKind, terminal: bool, detail: String) {
        self.push(TraceEvent {
            id,
            kind,
            at_nanos: clock::now_nanos(),
            terminal,
            remote: false,
            detail,
        });
    }

    /// Ingest worker-side events for `id` from a reply (stamps are
    /// worker-local; the `remote` flag marks them as such).
    pub fn ingest_remote(&self, events: Vec<TraceEvent>) {
        for mut ev in events {
            ev.remote = true;
            self.push(ev);
        }
    }

    /// Drain and return every event for `id` — the worker side of the
    /// reply piggyback (events leave the worker tracer so a long-lived
    /// worker does not re-ship or accumulate them).
    pub fn take_for(&self, id: u64) -> Vec<TraceEvent> {
        let mut events = obs_lock(&self.events);
        let mut taken = Vec::new();
        let mut kept = Vec::with_capacity(events.len());
        for ev in events.drain(..) {
            if ev.id == id {
                taken.push(ev);
            } else {
                kept.push(ev);
            }
        }
        *events = kept;
        taken
    }

    /// The timeline of one request, in insertion order.
    pub fn timeline(&self, id: u64) -> Vec<TraceEvent> {
        obs_lock(&self.events).iter().filter(|e| e.id == id).cloned().collect()
    }

    /// Every timeline, keyed by request id (deterministic order).
    pub fn timelines(&self) -> BTreeMap<u64, Vec<TraceEvent>> {
        let mut out: BTreeMap<u64, Vec<TraceEvent>> = BTreeMap::new();
        for ev in obs_lock(&self.events).iter() {
            out.entry(ev.id).or_default().push(ev.clone());
        }
        out
    }

    /// Terminal events per request id (the conservation property
    /// counts these — exactly one per submitted id).
    pub fn terminal_counts(&self) -> BTreeMap<u64, usize> {
        let mut out: BTreeMap<u64, usize> = BTreeMap::new();
        for ev in obs_lock(&self.events).iter() {
            if ev.terminal {
                *out.entry(ev.id).or_default() += 1;
            }
        }
        out
    }

    /// Events recorded (and retained) so far.
    pub fn len(&self) -> usize {
        obs_lock(&self.events).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events discarded past the capacity cap.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Forget everything (tests; the bench's paired overhead runs).
    pub fn clear(&self) {
        obs_lock(&self.events).clear();
        self.dropped.store(0, Ordering::Relaxed);
    }

    /// The `timelines` section of an `immsched.obs/v1` dump: request
    /// id (hex) → event array, id-ordered.
    pub fn timelines_json(&self) -> Json {
        let mut fields = Vec::new();
        for (id, events) in self.timelines() {
            fields.push((
                format!("{id:016x}"),
                Json::Arr(events.iter().map(TraceEvent::to_json).collect()),
            ));
        }
        Json::Obj(fields)
    }
}

/// The process tracer.
static GLOBAL: Lazy<Tracer> = Lazy::new(Tracer::default);

/// Gate for the convenience recorders below: disabled tracing costs
/// one relaxed atomic load per probe, no lock, no allocation.
static ENABLED: AtomicBool = AtomicBool::new(false);

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The process tracer (direct access for dump tooling and tests).
pub fn tracer() -> &'static Tracer {
    &GLOBAL
}

/// Record a span with no detail (when tracing is enabled).
pub fn span(id: u64, kind: SpanKind) {
    if enabled() {
        GLOBAL.record(id, kind, false, String::new());
    }
}

/// Record a span with lazily built detail — the closure only runs (and
/// allocates) when tracing is enabled.
pub fn span_with(id: u64, kind: SpanKind, detail: impl FnOnce() -> String) {
    if enabled() {
        GLOBAL.record(id, kind, false, detail());
    }
}

/// Record the *terminal* event of a request (driver / fleet-shed
/// paths only — exactly one per request life).
pub fn terminal(id: u64, kind: SpanKind, detail: impl FnOnce() -> String) {
    if enabled() {
        GLOBAL.record(id, kind, true, detail());
    }
}

/// Ingest worker-side spans from a reply into the process tracer.
pub fn ingest_remote(events: Vec<TraceEvent>) {
    if enabled() && !events.is_empty() {
        GLOBAL.ingest_remote(events);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_names_round_trip() {
        for kind in [
            SpanKind::Submit,
            SpanKind::Admit,
            SpanKind::Shed,
            SpanKind::Route,
            SpanKind::Slice,
            SpanKind::Preempt,
            SpanKind::Snapshot,
            SpanKind::Resume,
            SpanKind::Replay,
            SpanKind::Redial,
            SpanKind::Resubmit,
            SpanKind::Fault,
            SpanKind::Done,
            SpanKind::Cancelled,
            SpanKind::Failed,
        ] {
            assert_eq!(SpanKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(SpanKind::from_name("not-a-kind"), None);
    }

    #[test]
    fn timelines_group_by_id_and_keep_order() {
        let t = Tracer::with_capacity(64);
        t.record(2, SpanKind::Submit, false, String::new());
        t.record(1, SpanKind::Submit, false, String::new());
        t.record(2, SpanKind::Admit, false, "evicted=0".into());
        t.record(1, SpanKind::Done, true, String::new());
        let lines = t.timelines();
        assert_eq!(lines.keys().copied().collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(
            lines[&2].iter().map(|e| e.kind).collect::<Vec<_>>(),
            vec![SpanKind::Submit, SpanKind::Admit]
        );
        assert_eq!(t.terminal_counts().get(&1), Some(&1));
        assert_eq!(t.terminal_counts().get(&2), None);
    }

    #[test]
    fn take_for_drains_only_that_request() {
        let t = Tracer::with_capacity(64);
        t.record(5, SpanKind::Submit, false, String::new());
        t.record(6, SpanKind::Submit, false, String::new());
        t.record(5, SpanKind::Done, true, String::new());
        let taken = t.take_for(5);
        assert_eq!(taken.len(), 2);
        assert_eq!(t.len(), 1);
        assert!(t.timeline(5).is_empty());
        assert_eq!(t.timeline(6).len(), 1);
    }

    #[test]
    fn capacity_cap_drops_and_counts() {
        let t = Tracer::with_capacity(2);
        for i in 0..5u64 {
            t.record(i, SpanKind::Submit, false, String::new());
        }
        assert_eq!(t.len(), 2);
        assert_eq!(t.dropped(), 3);
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ingest_marks_events_remote() {
        let t = Tracer::with_capacity(8);
        t.ingest_remote(vec![TraceEvent {
            id: 9,
            kind: SpanKind::Admit,
            at_nanos: 1,
            terminal: false,
            remote: false,
            detail: String::new(),
        }]);
        assert!(t.timeline(9)[0].remote);
    }

    #[test]
    fn timelines_json_is_hex_keyed_and_parses() {
        let t = Tracer::with_capacity(8);
        t.record(u64::MAX, SpanKind::Done, true, "shard=1".into());
        let doc = t.timelines_json().render();
        let back = Json::parse(&doc).expect("valid JSON");
        let line = back.get("ffffffffffffffff").and_then(Json::as_array).expect("hex key");
        assert_eq!(line[0].get("kind").and_then(Json::as_str), Some("done"));
        assert_eq!(line[0].get("terminal").and_then(Json::as_bool), Some(true));
    }
}
