//! Bench: paper Fig. 8 — normalized energy efficiency (throughput per
//! joule) across platforms and workload classes.
//!
//! Paper means: ×918.6 / ×927.9 / ×2722.2 / ×2092.7 vs PREMA / CD-MSA /
//! Planaria / MoCA, ×3.43 vs IsoSched.  Expected shape: the TSS-vs-LTS
//! gap is the dominant term (DRAM round-trips vs on-chip links) and
//! grows with workload complexity; IMMSched beats IsoSched by a small
//! factor (cheaper scheduling energy + fewer missed-task retries).

use immsched::report::{self, figures};

fn main() -> anyhow::Result<()> {
    let params = figures::FigureParams::default();
    let t0 = std::time::Instant::now();
    let grid = figures::run_grid(&params);
    report::emit(&figures::fig8(&grid), "fig8_energy")?;
    println!("[bench] fig8 regenerated in {:?} (36 simulations)", t0.elapsed());
    Ok(())
}
