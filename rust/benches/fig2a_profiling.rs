//! Bench: paper Fig. 2a — scheduling time vs execution time for the
//! CPU-serial preemptive baseline on the Cloud platform (Scenario A =
//! UNet, Scenario B = Qwen), plus IMMSched's on-accelerator episode for
//! the same interrupts.
//!
//! Expected shape: sched/exec ≫ 1 for the serial baseline (the paper
//! reports orders of magnitude), while IMMSched's episode is far below
//! the execution time.

use immsched::report::{self, figures};

fn main() -> anyhow::Result<()> {
    let params = figures::FigureParams::default();
    let t0 = std::time::Instant::now();
    let table = figures::fig2a(&params);
    report::emit(&table, "fig2a_profiling")?;
    println!("[bench] fig2a regenerated in {:?}", t0.elapsed());
    Ok(())
}
