//! Bench: paper Fig. 7 — normalized Latency-Bound Throughput: the
//! maximum sustainable Poisson rate λ (deadline hit rate ≥ 90%) per
//! framework, platform and workload class.
//!
//! Paper means: ×89.8 / ×130.2 / ×191.4 / ×72.7 vs PREMA / CD-MSA /
//! Planaria / MoCA, ×3.4 vs IsoSched.  Expected shape here: the LTS
//! baselines saturate at rates orders of magnitude below IMMSched
//! (their scheduling latency eats the deadline budget), IsoSched sits a
//! small factor below.

use immsched::report::{self, figures};

fn main() -> anyhow::Result<()> {
    let params = figures::FigureParams::default();
    let t0 = std::time::Instant::now();
    report::emit(&figures::fig7(&params), "fig7_lbt")?;
    println!("[bench] fig7 regenerated in {:?} (λ bisection per cell)", t0.elapsed());
    Ok(())
}
