//! Bench: regenerate paper Table 1 (framework capability matrix) and
//! Table 2 (platforms).  Trivially fast; exists so `cargo bench` covers
//! every table and figure of the evaluation.

use immsched::report::{self, figures};

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    report::emit(&figures::table1(), "table1_capabilities")?;
    report::emit(&figures::table2(), "table2_platforms")?;
    println!("[bench] table1+table2 regenerated in {:?}", t0.elapsed());
    Ok(())
}
