//! Bench: paper Fig. 6 — normalized Speedup across platforms (Edge,
//! Cloud) and workload classes (Simple, Middle, Complex) for all six
//! frameworks.
//!
//! Paper means: ×34.4 / ×51.4 / ×81.4 / ×27.9 vs PREMA / CD-MSA /
//! Planaria / MoCA, ×1.6 vs IsoSched.  The reproduction target is the
//! *shape*: every LTS gap is 1-2 orders of magnitude and grows with
//! workload complexity; the IsoSched gap is a small-integer factor.

use immsched::report::{self, figures};

fn main() -> anyhow::Result<()> {
    let params = figures::FigureParams::default();
    let t0 = std::time::Instant::now();
    let grid = figures::run_grid(&params);
    report::emit(&figures::fig6(&grid), "fig6_speedup")?;
    println!("[bench] fig6 regenerated in {:?} (36 simulations)", t0.elapsed());
    Ok(())
}
