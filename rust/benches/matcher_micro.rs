//! Micro-bench: the matcher engines in isolation (not a paper figure —
//! the ablation DESIGN.md calls out).
//!
//! Measures host wall-clock of: serial Ullmann, float PSO (serial *and*
//! threaded epoch — the headline parallelism of the paper), quantized
//! PSO, greedy vs Hungarian projection, the native epoch backend per
//! size class, and the PJRT epoch (`pjrt` feature + built artifacts).
//! Feeds EXPERIMENTS.md §Perf.

use std::time::Instant;

use immsched::matcher::{
    project_greedy, project_hungarian, ullmann::plant_embedding, ullmann_find_first, PsoConfig,
    PsoMatcher, QuantizedMatcher,
};
use immsched::report;
use immsched::runtime::{default_backends, EpochBackend, EpochInputs};
use immsched::util::table::{fmt_time, Table};
use immsched::util::{MatF, Rng};

fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("[bench] threaded epoch path: {threads} worker threads available");
    let mut t = Table::new("matcher micro-benchmarks (host wall-clock)").header(&[
        "n",
        "m",
        "ullmann",
        "pso serial",
        "pso threaded",
        "pso q8",
        "proj greedy",
        "proj hungarian",
    ]);

    for &(n, m) in &[(8usize, 16usize), (16, 32), (32, 64), (64, 128)] {
        let (q, g, _) = plant_embedding(n, m, 0.3, 0.1, &mut rng);
        let mask = MatF::full(n, m, 1.0);
        // particles ≥ 8 so the threaded epoch has real fan-out to show
        let cfg = PsoConfig {
            seed: 11,
            epochs: 2,
            particles: 16,
            early_exit: true,
            ..Default::default()
        };

        let (_, t_ull) = timed(|| ullmann_find_first(&mask, &q, &g, 200_000));
        let (serial_out, t_serial) = timed(|| PsoMatcher::new(cfg).run_serial(&mask, &q, &g));
        let (threaded_out, t_threaded) = timed(|| PsoMatcher::new(cfg).run_threaded(&mask, &q, &g));
        // the threaded epoch must be a pure speedup, never a divergence
        assert_eq!(serial_out.fitness_trace, threaded_out.fitness_trace);
        assert_eq!(serial_out.mappings, threaded_out.mappings);
        let (_, t_q8) = timed(|| QuantizedMatcher::new(cfg).run(&mask, &q, &g));

        let mut s = MatF::from_fn(n, m, |_, _| rng.f32());
        s.row_normalize();
        let (_, t_pg) = timed(|| project_greedy(&s, &mask));
        let (_, t_ph) = timed(|| project_hungarian(&s, &mask));

        t.row(vec![
            n.to_string(),
            m.to_string(),
            fmt_time(t_ull),
            fmt_time(t_serial),
            fmt_time(t_threaded),
            fmt_time(t_q8),
            fmt_time(t_pg),
            fmt_time(t_ph),
        ]);
    }
    report::emit(&t, "matcher_micro")?;

    // native epoch backend timing per size class (the default epoch
    // path of the global controller)
    let mut t = Table::new("native epoch backend (per size class)").header(&[
        "class", "n", "m", "particles", "K", "epoch (warm, mean of 10)",
    ]);
    for mut backend in default_backends() {
        let class = backend.class();
        let mut inputs = EpochInputs::zeros(class);
        inputs.mask.iter_mut().for_each(|x| *x = 1.0);
        // warm-up
        backend.run_epoch(&inputs)?;
        let (_, t_epoch) = timed(|| {
            for i in 0..10 {
                inputs.seed = i;
                backend.run_epoch(&inputs).expect("epoch");
            }
        });
        t.row(vec![
            backend.name().to_string(),
            class.n.to_string(),
            class.m.to_string(),
            class.particles.to_string(),
            class.k_steps.to_string(),
            fmt_time(t_epoch / 10.0),
        ]);
    }
    report::emit(&t, "native_epoch_micro")?;

    bench_pjrt()?;
    Ok(())
}

/// PJRT epoch timing per size class (compile once, run many).
#[cfg(feature = "pjrt")]
fn bench_pjrt() -> anyhow::Result<()> {
    use immsched::runtime::{ArtifactRegistry, EpochRunner, RuntimeClient};
    let Ok(registry) = ArtifactRegistry::discover(&ArtifactRegistry::default_dir()) else {
        println!("[bench] artifacts not built — skipping PJRT micro-bench");
        return Ok(());
    };
    let client = RuntimeClient::cpu()?;
    let mut t = Table::new("PJRT epoch (per artifact size class)").header(&[
        "class", "n", "m", "particles", "compile", "epoch (warm, mean of 10)",
    ]);
    for artifact in registry.all() {
        let (runner, t_compile) = timed(|| EpochRunner::load(&client, artifact));
        let runner = runner?;
        let class = runner.class();
        let mut inputs = EpochInputs::zeros(class);
        inputs.mask.iter_mut().for_each(|x| *x = 1.0);
        // warm-up
        runner.run(&inputs)?;
        let (_, t_epoch) = timed(|| {
            for i in 0..10 {
                inputs.seed = i;
                runner.run(&inputs).expect("epoch");
            }
        });
        t.row(vec![
            runner.name().to_string(),
            class.n.to_string(),
            class.m.to_string(),
            class.particles.to_string(),
            fmt_time(t_compile),
            fmt_time(t_epoch / 10.0),
        ]);
    }
    report::emit(&t, "pjrt_epoch_micro")?;
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn bench_pjrt() -> anyhow::Result<()> {
    println!("[bench] pjrt feature disabled — native epoch backend covered above");
    Ok(())
}
