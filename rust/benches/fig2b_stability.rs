//! Bench: paper Fig. 2b — search stability of the PSO matcher with vs
//! without the probabilistic continuous relaxation.
//!
//! Emits both the summary table and the averaged best-so-far fitness
//! traces (reports/fig2b_traces.csv) for plotting.
//!
//! Expected shape: the relaxed variant converges higher and with lower
//! across-seed variance than the discrete coupling.

use immsched::report::{self, figures};

fn main() -> anyhow::Result<()> {
    let params = figures::FigureParams::default();
    let t0 = std::time::Instant::now();
    let (table, xs, series) = figures::fig2b(&params);
    report::emit(&table, "fig2b_stability")?;
    report::emit_series("fig2b_traces", "step", &["relaxed", "discrete"], &xs, &series)?;
    println!("[bench] fig2b regenerated in {:?}", t0.elapsed());
    Ok(())
}
