//! Ablation bench (DESIGN.md §6 "ablation benches for the design
//! choices"): isolates each IMMSched design decision on a fixed pool of
//! planted instances.
//!
//!   1. consensus term (c3 > 0 vs c3 = 0) — the paper's global
//!      controller contribution;
//!   2. particle count (engine-parallel width);
//!   3. quantization (u8/i32 vs f32 search);
//!   4. serial engines: Ullmann vs VF2 (state counts);
//!   5. projection: greedy (comparator tree) vs Hungarian.

use immsched::matcher::{
    project_greedy, project_hungarian, projection::projection_weight,
    ullmann::plant_embedding, ullmann_find_first, vf2_find_first, PsoConfig, PsoMatcher,
    QuantizedMatcher,
};
use immsched::report;
use immsched::util::table::Table;
use immsched::util::{MatF, Rng};

const INSTANCES: usize = 12;
const N: usize = 10;
const M: usize = 30;

fn instance_pool() -> Vec<(MatF, MatF)> {
    let mut rng = Rng::new(424242);
    (0..INSTANCES).map(|_| {
        // dense targets: many embeddings exist, so the *swarm alone*
        // (repair disabled) can land exact projections and the variants
        // separate on match rate, not just fitness
        let (q, g, _) = plant_embedding(N, M, 0.3, 0.4, &mut rng);
        (q, g)
    }).collect()
}

fn main() -> anyhow::Result<()> {
    let pool = instance_pool();
    let mask = MatF::full(N, M, 1.0);

    // --- 1+2+3: swarm ablations -----------------------------------------
    let mut t = Table::new(format!(
        "swarm ablations on {INSTANCES} planted instances (n={N}, m={M}, no Ullmann repair)"
    ))
    .header(&["variant", "matched", "mean best fitness", "mean steps to match"]);

    let base = PsoConfig {
        epochs: 6,
        steps: 24,
        early_exit: true,
        repair_budget: 0, // isolate the swarm
        ..Default::default()
    };
    let variants: Vec<(&str, PsoConfig, bool)> = vec![
        ("full (consensus, 16 particles, f32)", base, false),
        ("no consensus (c3 = 0)", PsoConfig { c3: 0.0, ..base }, false),
        ("4 particles", PsoConfig { particles: 4, ..base }, false),
        ("64 particles", PsoConfig { particles: 64, ..base }, false),
        ("quantized u8/i32", base, true),
    ];
    for (name, cfg, quantized) in variants {
        let mut matched = 0usize;
        let mut fitness_sum = 0.0f64;
        let mut steps_sum = 0usize;
        for (i, (q, g)) in pool.iter().enumerate() {
            let cfg = PsoConfig { seed: 1000 + i as u64, ..cfg };
            let (ok, fit, steps) = if quantized {
                let out = QuantizedMatcher::new(cfg).run(&mask, q, g);
                (out.matched(), out.best_fitness, out.steps_run)
            } else {
                let out = PsoMatcher::new(cfg).run(&mask, q, g);
                (out.matched(), out.best_fitness, out.steps_run)
            };
            matched += ok as usize;
            fitness_sum += fit as f64;
            if ok {
                steps_sum += steps;
            }
        }
        t.row(vec![
            name.into(),
            format!("{matched}/{INSTANCES}"),
            format!("{:.3}", fitness_sum / INSTANCES as f64),
            if matched > 0 { format!("{:.1}", steps_sum as f64 / matched as f64) } else { "—".into() },
        ]);
    }
    report::emit(&t, "ablation_swarm")?;

    // --- 4: serial engines ------------------------------------------------
    let mut t = Table::new("serial engines on the same instances")
        .header(&["engine", "found", "mean states/nodes"]);
    let mut ull_nodes = 0u64;
    let mut ull_found = 0usize;
    let mut vf2_states = 0u64;
    let mut vf2_found = 0usize;
    for (q, g) in &pool {
        let (u, us) = ullmann_find_first(&mask, q, g, 10_000_000);
        ull_found += u.is_some() as usize;
        ull_nodes += us.nodes_visited;
        let (v, vs) = vf2_find_first(&mask, q, g, 10_000_000);
        vf2_found += v.is_some() as usize;
        vf2_states += vs.states;
    }
    t.row(vec![
        "Ullmann (refine+backtrack)".into(),
        format!("{ull_found}/{INSTANCES}"),
        format!("{:.0}", ull_nodes as f64 / INSTANCES as f64),
    ]);
    t.row(vec![
        "VF2 (frontier+lookahead)".into(),
        format!("{vf2_found}/{INSTANCES}"),
        format!("{:.0}", vf2_states as f64 / INSTANCES as f64),
    ]);
    report::emit(&t, "ablation_serial_engines")?;

    // --- 5: projection quality --------------------------------------------
    let mut t = Table::new("projection quality (selected S mass, higher = better)")
        .header(&["projector", "mean weight", "worst-case gap vs hungarian"]);
    let mut rng = Rng::new(7);
    let mut greedy_sum = 0.0f32;
    let mut hung_sum = 0.0f32;
    let mut worst_gap = 0.0f32;
    for _ in 0..50 {
        let mut s = MatF::from_fn(N, M, |_, _| rng.f32());
        s.row_normalize();
        let wg = projection_weight(&s, &project_greedy(&s, &mask));
        let wh = projection_weight(&s, &project_hungarian(&s, &mask));
        greedy_sum += wg;
        hung_sum += wh;
        worst_gap = worst_gap.max(wh - wg);
    }
    t.row(vec!["greedy (comparator tree, §3.4)".into(), format!("{:.4}", greedy_sum / 50.0), format!("{worst_gap:.4}")]);
    t.row(vec!["hungarian (O(n³))".into(), format!("{:.4}", hung_sum / 50.0), "0".into()]);
    report::emit(&t, "ablation_projection")?;

    Ok(())
}
