//! Admission-path behavior: the queue pops in (priority, deadline,
//! FIFO) order, expired/over-depth requests are shed before reaching
//! the controller, and in-flight episodes are interruptible at the
//! epoch barrier (explicit cancel + higher-priority preemption).

use std::time::Duration;

use immsched::coordinator::{
    CancelToken, GlobalController, MatchEngine, MatchPath, MatchProblem, MatchService,
    QuantizedEngine, QueuedRequest, RequestRouter, ServiceConfig, UllmannEngine, Vf2Engine,
};
use immsched::graph::{gen_chain, NodeKind};
use immsched::matcher::PsoConfig;
use immsched::scheduler::Priority;
use immsched::util::{MatF, Rng};

const PRIORITIES: [Priority; 3] = [Priority::Background, Priority::Normal, Priority::Urgent];

fn chain_problem(n: usize, m: usize) -> MatchProblem {
    let qd = gen_chain(n, NodeKind::Compute);
    let gd = gen_chain(m, NodeKind::Universal);
    MatchProblem::from_dags(&qd, &gd)
}

/// A problem with a full mask (no empty-row reject) that has **no**
/// embedding: a 3-fan-out star cannot map into a chain.  The PSO episode
/// runs every configured epoch unless something stops it — the
/// long-running victim for cancellation tests.
fn infeasible_full_mask_problem() -> MatchProblem {
    let mut q = MatF::zeros(4, 4);
    q[(0, 1)] = 1.0;
    q[(0, 2)] = 1.0;
    q[(0, 3)] = 1.0;
    let gd = gen_chain(8, NodeKind::Universal);
    MatchProblem::from_dense(&MatF::full(4, 8, 1.0), &q, &gd.adjacency())
}

/// Property: over random request mixes, the router pops exactly in
/// (priority desc, deadline asc, admission-FIFO) order — checked against
/// an independently sorted reference.
#[test]
fn queue_pops_in_priority_deadline_fifo_order() {
    let mut rng = Rng::new(0xADA);
    for trial in 0..60 {
        let count = rng.range(1, 24) as u64;
        let mut router = RequestRouter::new(64);
        let mut reference: Vec<(u8, f64, u64)> = Vec::new();
        for id in 0..count {
            let priority = *rng.choose(&PRIORITIES);
            let deadline = if rng.chance(0.5) { Some(1.0 + rng.f64() * 4.0) } else { None };
            let verdict = router.admit(QueuedRequest::new(id, priority, deadline, 0.0), 0.0);
            assert!(verdict.admitted(), "trial {trial}: admit {id}");
            let rank = match priority {
                Priority::Urgent => 0u8,
                Priority::Normal => 1,
                Priority::Background => 2,
            };
            reference.push((rank, deadline.unwrap_or(f64::INFINITY), id));
        }
        reference.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)).then(a.2.cmp(&b.2)));
        let got: Vec<u64> = std::iter::from_fn(|| router.next(0.5)).map(|r| r.id).collect();
        let want: Vec<u64> = reference.iter().map(|r| r.2).collect();
        assert_eq!(got, want, "trial {trial}");
    }
}

/// Property: whatever the shed pattern, every admitted-or-shed request
/// is accounted for exactly once (no silent drops at capacity).
#[test]
fn queue_conserves_requests_under_capacity_pressure() {
    let mut rng = Rng::new(0xCAFE);
    for trial in 0..40 {
        let capacity = rng.range(1, 6);
        let mut router = RequestRouter::new(capacity);
        let total = rng.range(4, 30) as u64;
        let mut evicted_count = 0u64;
        let mut shed_on_admit = 0u64;
        for id in 0..total {
            let priority = *rng.choose(&PRIORITIES);
            match router.admit(QueuedRequest::new(id, priority, None, 0.0), 0.0) {
                immsched::coordinator::Admission::Admitted { evicted } => {
                    evicted_count += u64::from(evicted.is_some());
                }
                immsched::coordinator::Admission::Shed => shed_on_admit += 1,
            }
        }
        let remaining = std::iter::from_fn(|| router.next(0.0)).count() as u64;
        assert_eq!(
            remaining + evicted_count + shed_on_admit,
            total,
            "trial {trial}: lost requests (cap {capacity})"
        );
        assert!(remaining <= capacity as u64, "trial {trial}: depth bound violated");
    }
}

/// An already-expired deadline is shed at admission: the controller
/// never sees the request, and the caller gets a `Shed` response.
#[test]
fn expired_requests_are_shed_before_an_episode_is_wasted() {
    let service = MatchService::spawn(PsoConfig { seed: 3, ..Default::default() }).unwrap();
    let resp = service
        .match_blocking(chain_problem(4, 8), Priority::Urgent, Some(-1.0))
        .expect("service answers shed requests too");
    assert_eq!(resp.path, MatchPath::Shed);
    assert!(!resp.matched());
    let stats = service.stats();
    assert_eq!(stats.controller.requests, 0, "shed request must not reach the controller");
    assert_eq!(stats.router.shed_expired, 1);

    // a live-deadline request on the same service still gets served
    let resp = service
        .match_blocking(chain_problem(4, 8), Priority::Urgent, Some(service.now() + 60.0))
        .unwrap();
    assert!(resp.matched());
    assert_eq!(service.stats().controller.requests, 1);
}

/// Three different engines are selectable behind the *same*
/// `MatchService` call — the chain is configuration, not code.
#[test]
fn three_engines_selectable_behind_one_service_api() {
    for (name, want) in [
        ("quantized", MatchPath::NativeFallback),
        ("ullmann", MatchPath::Ullmann),
        ("vf2", MatchPath::Vf2),
    ] {
        let service = MatchService::spawn_with(
            ServiceConfig::default(),
            Box::new(move || {
                let engine: Box<dyn MatchEngine> = match name {
                    "quantized" => {
                        Box::new(QuantizedEngine::new(PsoConfig { seed: 2, ..Default::default() }))
                    }
                    "ullmann" => Box::new(UllmannEngine),
                    _ => Box::new(Vf2Engine),
                };
                GlobalController::with_engines(vec![engine])
            }),
        )
        .unwrap();
        let resp = service.match_blocking(chain_problem(4, 8), Priority::Urgent, None).unwrap();
        assert!(resp.matched(), "{name} found no mapping");
        assert_eq!(resp.path, want, "{name} served on the wrong path");
    }
}

/// The paper's interruptibility mechanism, isolated: a cancel lands at
/// the epoch barrier and the episode stops there — far short of its
/// configured epoch budget, with the cancellation counted.
#[test]
fn cancel_token_interrupts_episode_at_epoch_barrier() {
    let cfg = PsoConfig { seed: 7, epochs: 1_000_000, repair_budget: 1_000, ..Default::default() };
    let mut ctl = GlobalController::new(cfg).expect("controller");
    let problem = infeasible_full_mask_problem();
    let cancel = CancelToken::new();
    let canceller = cancel.clone();
    let killer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(40));
        canceller.cancel();
    });
    let out = ctl.serve(&problem.request(1, Priority::Background, None), &cancel);
    killer.join().unwrap();
    assert_eq!(out.path, MatchPath::Cancelled);
    assert!(out.epochs_run < 1_000_000, "episode must stop at the barrier");
    assert!(!out.matched());
    assert_eq!(ctl.stats().cancelled, 1);
}

/// End-to-end preemption: a higher-priority arrival interrupts the
/// lower-priority episode already running on the service thread; the
/// urgent request is served, the victim answers `Cancelled`.
#[test]
fn higher_priority_arrival_preempts_running_episode() {
    let cfg = PsoConfig { seed: 9, epochs: 1_000_000, repair_budget: 1_000, ..Default::default() };
    let service = MatchService::spawn(cfg).unwrap();
    let victim =
        service.submit(infeasible_full_mask_problem(), Priority::Background, None).unwrap();
    // wait until the victim's episode actually occupies the controller
    let mut waited = 0;
    while service.in_flight() != Some(Priority::Background) {
        std::thread::sleep(Duration::from_millis(2));
        waited += 1;
        assert!(waited < 5_000, "victim episode never started");
    }
    let urgent = service.match_blocking(chain_problem(4, 8), Priority::Urgent, None).unwrap();
    assert!(urgent.matched(), "urgent request must be served after the preemption");
    let victim_resp = victim.wait().unwrap();
    assert_eq!(
        victim_resp.path,
        MatchPath::Cancelled,
        "lower-priority episode must yield at the epoch barrier"
    );
    let stats = service.stats();
    assert_eq!(stats.controller.cancelled, 1);
    assert_eq!(stats.controller.requests, 2);
}

/// A deadline that expires *during* the episode stops it at the next
/// epoch barrier — expiry is enforced mid-episode, not only at
/// admission.
#[test]
fn deadline_expiry_interrupts_episode_at_epoch_barrier() {
    let cfg = PsoConfig { seed: 13, epochs: 1_000_000, repair_budget: 1_000, ..Default::default() };
    let service = MatchService::spawn(cfg).unwrap();
    let deadline = service.now() + 0.15;
    let resp = service
        .match_blocking(infeasible_full_mask_problem(), Priority::Normal, Some(deadline))
        .unwrap();
    assert_eq!(resp.path, MatchPath::Cancelled, "expiry must interrupt the running episode");
    assert!(resp.epochs_run < 1_000_000);
    assert_eq!(service.stats().controller.cancelled, 1);
}

/// Explicit caller-side cancellation through the ticket.
#[test]
fn ticket_cancel_stops_episode() {
    let cfg = PsoConfig { seed: 11, epochs: 1_000_000, repair_budget: 1_000, ..Default::default() };
    let service = MatchService::spawn(cfg).unwrap();
    let ticket = service.submit(infeasible_full_mask_problem(), Priority::Normal, None).unwrap();
    std::thread::sleep(Duration::from_millis(20));
    ticket.cancel();
    let resp = ticket.wait().unwrap();
    assert_eq!(resp.path, MatchPath::Cancelled);
    assert!(!resp.matched());
}
