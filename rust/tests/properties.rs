//! Property-based tests over the matcher and scheduler invariants
//! (in-repo `immsched::testing` framework — offline proptest substitute,
//! DESIGN.md §4).

use immsched::graph::{gen_random_dag, is_acyclic, Csr, NodeKind};
use immsched::matcher::{
    build_bitmask, build_mask, edge_fitness, elite_consensus, has_empty_row, mapping_is_feasible,
    mapping_is_feasible_csr, project_greedy, project_hungarian, ullmann::plant_embedding,
    ullmann_find_first, BitMask, FitnessKernel, PsoConfig, PsoMatcher, QuantizedMatcher,
};
use immsched::testing::{property, property_res, Gen};
use immsched::util::MatF;

fn random_stochastic(g: &mut Gen, n: usize, m: usize) -> MatF {
    let mut s = MatF::from_fn(n, m, |_, _| g.f32() + 1e-3);
    s.row_normalize();
    s
}

/// Ullmann soundness: anything it returns is a real embedding.
#[test]
fn prop_ullmann_sound() {
    property_res("ullmann sound", 60, |g| {
        let n = g.usize_in(2..7);
        let m = n + g.usize_in(1..8);
        let qd = g.f64() * 0.6;
        let ed = g.f64() * 0.3;
        let (q, gg, _) = plant_embedding(n, m, qd, ed, g.rng());
        let mask = MatF::full(n, m, 1.0);
        let (found, _) = ullmann_find_first(&mask, &q, &gg, 2_000_000);
        match found {
            Some(mp) if !mapping_is_feasible(&mp, &q, &gg) => {
                Err(format!("unsound mapping {mp:?}"))
            }
            _ => Ok(()),
        }
    });
}

/// Ullmann completeness: planted embeddings are always found (generous
/// budget).
#[test]
fn prop_ullmann_complete_on_planted() {
    property_res("ullmann complete", 40, |g| {
        let n = g.usize_in(2..6);
        let m = n + g.usize_in(2..8);
        let (q, gg, _) = plant_embedding(n, m, 0.5, 0.15, g.rng());
        let mask = MatF::full(n, m, 1.0);
        let (found, _) = ullmann_find_first(&mask, &q, &gg, 10_000_000);
        found.map(|_| ()).ok_or_else(|| "planted embedding missed".to_string())
    });
}

/// Projection invariants: totality under full mask, injectivity, mask
/// respect — for both greedy and Hungarian.
#[test]
fn prop_projection_injective_and_masked() {
    property_res("projection invariants", 80, |g| {
        let n = g.usize_in(1..8);
        let m = n + g.usize_in(0..8);
        let s = random_stochastic(g, n, m);
        let mask = MatF::from_fn(n, m, |_, _| if g.bool(0.8) { 1.0 } else { 0.0 });
        for proj in [project_greedy(&s, &mask), project_hungarian(&s, &mask)] {
            let mut seen = std::collections::HashSet::new();
            for (i, &mj) in proj.iter().enumerate() {
                if let Some(j) = mj {
                    if mask[(i, j)] == 0.0 {
                        return Err(format!("({i},{j}) violates mask"));
                    }
                    if !seen.insert(j) {
                        return Err(format!("column {j} used twice"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Consensus stays row-stochastic for arbitrary particle sets.
#[test]
fn prop_consensus_row_stochastic() {
    property_res("consensus row-stochastic", 60, |g| {
        let n = g.usize_in(1..6);
        let m = g.usize_in(2..10);
        let count = g.usize_in(1..8);
        let parts: Vec<MatF> = (0..count).map(|_| random_stochastic(g, n, m)).collect();
        let fit: Vec<f32> = (0..count).map(|_| -g.f32() * 100.0).collect();
        let elite = g.usize_in(1..6);
        let c = elite_consensus(&parts, &fit, elite);
        for i in 0..n {
            let sum: f32 = c.row(i).iter().sum();
            if (sum - 1.0).abs() > 1e-4 {
                return Err(format!("row {i} sums to {sum}"));
            }
        }
        Ok(())
    });
}

/// Fitness of a *feasible* discrete mapping is exactly 0 when the target
/// subgraph induced by the mapping has no extra edges among mapped
/// vertices beyond Q — and never positive in general.
#[test]
fn prop_fitness_nonpositive() {
    property("fitness nonpositive", 60, |g| {
        let n = g.usize_in(1..6);
        let m = n + g.usize_in(1..8);
        let s = random_stochastic(g, n, m);
        let q = gen_random_dag(n, 0.4, g.rng(), NodeKind::Compute).adjacency();
        let gg = gen_random_dag(m, 0.4, g.rng(), NodeKind::Universal).adjacency();
        edge_fitness(&s, &q, &gg) <= 1e-6
    });
}

/// The two PSO matchers never return an infeasible mapping (soundness
/// is enforced by the Ullmann-style verification step).
#[test]
fn prop_pso_matchers_sound() {
    property_res("pso matchers sound", 25, |g| {
        let n = g.usize_in(3..7);
        let m = n + g.usize_in(3..10);
        let (q, gg, _) = plant_embedding(n, m, 0.4, 0.2, g.rng());
        let mask = MatF::full(n, m, 1.0);
        let cfg = PsoConfig { seed: g.rng().next_u64(), epochs: 2, ..Default::default() };
        let float_out = PsoMatcher::new(cfg).run(&mask, &q, &gg);
        let q8_out = QuantizedMatcher::new(cfg).run(&mask, &q, &gg);
        for mp in float_out.mappings.iter().chain(&q8_out.mappings) {
            if !mapping_is_feasible(mp, &q, &gg) {
                return Err(format!("infeasible mapping escaped: {mp:?}"));
            }
        }
        Ok(())
    });
}

/// Compatibility mask soundness: a pair masked out can never appear in
/// any feasible mapping (degree/kind filters are necessary conditions).
#[test]
fn prop_mask_is_sound() {
    property_res("mask soundness", 40, |g| {
        let n = g.usize_in(2..6);
        let m = n + g.usize_in(1..7);
        let qd = gen_random_dag(n, 0.4, g.rng(), NodeKind::Compute);
        let gd = gen_random_dag(m, 0.5, g.rng(), NodeKind::Universal);
        let mask = build_mask(&qd, &gd);
        let (q, gg) = (qd.adjacency(), gd.adjacency());
        // exhaustive check on small instances: any feasible mapping only
        // uses mask-allowed pairs
        let (found, _) = ullmann_find_first(&MatF::full(n, m, 1.0), &q, &gg, 2_000_000);
        if let Some(mp) = found {
            for (i, &mj) in mp.iter().enumerate() {
                let j = mj.unwrap();
                if mask[(i, j)] == 0.0 {
                    return Err(format!("mask wrongly excludes feasible pair ({i},{j})"));
                }
            }
        }
        Ok(())
    });
}

/// Tiling invariants on random layered workloads are covered in the
/// workload module; here: the target graph is acyclic for arbitrary
/// preemptible subsets.
#[test]
fn prop_target_graph_acyclic() {
    use immsched::accel::{build_target_graph, Platform};
    property("target graph acyclic", 40, |g| {
        let p = Platform::edge();
        let pre: Vec<bool> = (0..p.engines).map(|_| g.bool(0.5)).collect();
        let (dag, map) = build_target_graph(&p, &pre);
        is_acyclic(&dag) && map.len() == pre.iter().filter(|&&b| b).count()
    });
}

/// Simulator conservation under random traces: every record accounted,
/// no start-before-arrival, no completion-before-start.
#[test]
fn prop_sim_conservation() {
    use immsched::accel::Platform;
    use immsched::scheduler::{build_trace, FrameworkKind, SimConfig, Simulator, TraceConfig};
    use immsched::workload::WorkloadClass;
    property_res("sim conservation", 8, |g| {
        let framework = *g
            .rng()
            .choose(&[FrameworkKind::ImmSched, FrameworkKind::IsoSched, FrameworkKind::Moca]);
        let cfg = SimConfig { framework, ..Default::default() };
        let platform = Platform::get(cfg.platform_kind);
        let trace_cfg = TraceConfig {
            class: WorkloadClass::Simple,
            arrival_rate: 20.0 + g.f64() * 120.0,
            horizon: 0.015,
            seed: g.rng().next_u64(),
            ..Default::default()
        };
        let tasks = build_trace(&trace_cfg, &platform);
        let n = tasks.len();
        let res = Simulator::new(cfg).run(tasks, trace_cfg.horizon);
        if res.records.len() != n {
            return Err(format!("{} records for {n} tasks", res.records.len()));
        }
        for r in &res.records {
            if let Some(s) = r.started {
                if s + 1e-12 < r.arrival {
                    return Err(format!("task {} started before arrival", r.id));
                }
            }
            if let (Some(s), Some(c)) = (r.started, r.completed) {
                if c + 1e-12 < s {
                    return Err(format!("task {} completed before start", r.id));
                }
            }
            if r.completed.is_some() && r.started.is_none() {
                return Err(format!("task {} completed without starting", r.id));
            }
        }
        Ok(())
    });
}

/// Forked RNG streams are order-independent: the stream a particle
/// receives depends only on the fork order (fixed at epoch setup), never
/// on the order the streams are *consumed* in — the property that makes
/// the threaded epoch bit-identical to the serial one.
#[test]
fn prop_forked_streams_order_independent() {
    use immsched::util::Rng;
    property_res("forked streams order independent", 40, |g| {
        let seed = g.rng().next_u64();
        let count = g.usize_in(2..9);
        let draws = g.usize_in(1..64);
        let fork_all = |seed: u64| -> Vec<Rng> {
            let mut master = Rng::new(seed);
            (0..count).map(|i| master.fork(i as u64)).collect()
        };
        // consume streams forward
        let mut fwd = fork_all(seed);
        let forward: Vec<Vec<u64>> = fwd
            .iter_mut()
            .map(|r| (0..draws).map(|_| r.next_u64()).collect())
            .collect();
        // consume the same streams in reverse order
        let mut rev = fork_all(seed);
        let mut backward: Vec<Vec<u64>> = vec![Vec::new(); count];
        for i in (0..count).rev() {
            backward[i] = (0..draws).map(|_| rev[i].next_u64()).collect();
        }
        // and interleaved round-robin
        let mut inter = fork_all(seed);
        let mut robin: Vec<Vec<u64>> = vec![Vec::new(); count];
        for _ in 0..draws {
            for (i, r) in inter.iter_mut().enumerate() {
                robin[i].push(r.next_u64());
            }
        }
        if forward != backward || forward != robin {
            return Err("forked stream output depends on consumption order".into());
        }
        Ok(())
    });
}

/// Determinism under parallelism: the threaded epoch produces the same
/// mappings and traces as the serial per-particle loop on arbitrary
/// planted instances.
#[test]
fn prop_threaded_pso_matches_serial() {
    property_res("threaded pso == serial pso", 10, |g| {
        let n = g.usize_in(3..7);
        let m = n + g.usize_in(3..10);
        let (q, gg, _) = plant_embedding(n, m, 0.4, 0.2, g.rng());
        let mask = MatF::full(n, m, 1.0);
        let cfg = PsoConfig {
            seed: g.rng().next_u64(),
            epochs: 2,
            steps: 6,
            particles: 8,
            early_exit: false,
            ..Default::default()
        };
        let matcher = PsoMatcher::new(cfg);
        let a = matcher.run_serial(&mask, &q, &gg);
        let b = matcher.run_threaded(&mask, &q, &gg);
        if a.mappings != b.mappings {
            return Err("mappings diverged between serial and threaded epochs".into());
        }
        if a.fitness_trace != b.fitness_trace || a.mean_fitness_trace != b.mean_fitness_trace {
            return Err("fitness traces diverged between serial and threaded epochs".into());
        }
        Ok(())
    });
}

/// Degenerate PSO configs (no particles / steps / epochs) return empty
/// outcomes instead of panicking.
#[test]
fn prop_degenerate_pso_configs_are_safe() {
    property_res("degenerate pso configs safe", 12, |g| {
        let n = g.usize_in(2..5);
        let m = n + g.usize_in(1..6);
        let (q, gg, _) = plant_embedding(n, m, 0.4, 0.2, g.rng());
        let mask = MatF::full(n, m, 1.0);
        let zeroed = g.usize_in(0..3);
        let cfg = PsoConfig {
            particles: if zeroed == 0 { 0 } else { 4 },
            epochs: if zeroed == 1 { 0 } else { 2 },
            steps: if zeroed == 2 { 0 } else { 2 },
            seed: g.rng().next_u64(),
            ..Default::default()
        };
        let out = PsoMatcher::new(cfg).run(&mask, &q, &gg);
        if out.matched() || !out.fitness_trace.is_empty() {
            return Err(format!(
                "degenerate config (zeroed field {zeroed}) produced non-empty outcome"
            ));
        }
        Ok(())
    });
}

/// The sparse CSR fitness kernel is the dense `edge_fitness` oracle up
/// to floating-point summation order, on random DAG pairs with random
/// sparse masks (the masked zeros exercise the kernel's skip path).
#[test]
fn prop_sparse_fitness_matches_dense() {
    property_res("sparse fitness == dense", 60, |g| {
        let n = g.usize_in(1..10);
        let m = n + g.usize_in(0..12);
        let dq = g.f64() * 0.7;
        let dg = g.f64() * 0.7;
        let q = gen_random_dag(n, dq, g.rng(), NodeKind::Compute).adjacency();
        let gg = gen_random_dag(m, dg, g.rng(), NodeKind::Universal).adjacency();
        let mask = MatF::from_fn(n, m, |_, _| if g.bool(0.7) { 1.0 } else { 0.0 });
        let mut s = random_stochastic(g, n, m);
        s.hadamard_assign(&mask);
        s.row_normalize();
        let dense = edge_fitness(&s, &q, &gg);
        let kernel = FitnessKernel::new(&q, &gg);
        let mut scratch = kernel.scratch();
        let sparse = kernel.eval(s.as_slice(), &mut scratch);
        let tol = 1e-3 * (1.0 + dense.abs());
        if (dense - sparse).abs() > tol {
            return Err(format!("n={n} m={m}: dense {dense} vs sparse {sparse}"));
        }
        Ok(())
    });
}

/// Same agreement at every native epoch size class's exact dims (the
/// shapes the interrupt hot path actually runs).
#[test]
fn sparse_fitness_matches_dense_at_all_size_classes() {
    use immsched::runtime::NATIVE_SIZE_CLASSES;
    let mut rng = immsched::util::Rng::new(0xC1A55);
    for &(name, class) in NATIVE_SIZE_CLASSES.iter() {
        let (n, m) = (class.n, class.m);
        let q = gen_random_dag(n, (3.0 / n as f64).min(1.0), &mut rng, NodeKind::Compute)
            .adjacency();
        let gg = gen_random_dag(m, (3.0 / m as f64).min(1.0), &mut rng, NodeKind::Universal)
            .adjacency();
        let mut s = MatF::from_fn(n, m, |_, _| rng.f32() + 1e-3);
        s.row_normalize();
        let dense = edge_fitness(&s, &q, &gg);
        let kernel = FitnessKernel::new(&q, &gg);
        let mut scratch = kernel.scratch();
        let sparse = kernel.eval(s.as_slice(), &mut scratch);
        let tol = 2e-3 * (1.0 + dense.abs());
        assert!(
            (dense - sparse).abs() <= tol,
            "class {name}: dense {dense} vs sparse {sparse}"
        );
    }
}

/// The packed bitset mask is the dense f32 mask bit for bit: same
/// construction, same empty-row witness, lossless roundtrip. Column
/// counts beyond 64 cross word boundaries.
#[test]
fn prop_bitmask_matches_dense_mask() {
    property_res("bitmask == dense mask", 60, |g| {
        let n = g.usize_in(1..8);
        let m = g.usize_in(1..90);
        let qd = gen_random_dag(n, g.f64() * 0.6, g.rng(), NodeKind::Compute);
        let gd = gen_random_dag(m, g.f64() * 0.4, g.rng(), NodeKind::Universal);
        let bits = build_bitmask(&qd, &gd);
        let dense = build_mask(&qd, &gd);
        for i in 0..n {
            for j in 0..m {
                if bits.get(i, j) != (dense[(i, j)] != 0.0) {
                    return Err(format!("bit ({i},{j}) diverges"));
                }
            }
        }
        if bits.has_empty_row() != has_empty_row(&dense) {
            return Err("empty-row witness diverges".into());
        }
        if BitMask::from_matf(&dense) != bits {
            return Err("from_matf roundtrip diverges".into());
        }
        if (bits.density() - dense.sum() as f64 / (n * m) as f64).abs() > 1e-9 {
            return Err("density diverges".into());
        }
        Ok(())
    });
}

/// CSR-based feasibility is the dense scan on arbitrary (also invalid)
/// mappings: partial, duplicate, out-of-range, wrong-edge.
#[test]
fn prop_feasibility_csr_matches_dense() {
    property_res("feasibility csr == dense", 60, |g| {
        let n = g.usize_in(2..7);
        let m = n + g.usize_in(0..8);
        let qd = gen_random_dag(n, g.f64() * 0.8, g.rng(), NodeKind::Compute);
        let gd = gen_random_dag(m, g.f64() * 0.6, g.rng(), NodeKind::Universal);
        let (q, gg) = (qd.adjacency(), gd.adjacency());
        let q_csr = Csr::from_dense(&q);
        let mapping: Vec<Option<usize>> = (0..n)
            .map(|_| if g.bool(0.9) { Some(g.usize_in(0..m + 2)) } else { None })
            .collect();
        let dense = mapping_is_feasible(&mapping, &q, &gg);
        let csr = mapping_is_feasible_csr(&mapping, &q_csr, &gg);
        if dense != csr {
            return Err(format!("mapping {mapping:?}: dense {dense} vs csr {csr}"));
        }
        Ok(())
    });
}

/// Quantized and float matchers agree on feasibility for easy planted
/// instances (quantization must not break the search).
#[test]
fn prop_q8_tracks_float() {
    property_res("q8 tracks float", 15, |g| {
        let n = g.usize_in(3..6);
        let m = n + g.usize_in(4..10);
        let (q, gg, _) = plant_embedding(n, m, 0.35, 0.25, g.rng());
        let mask = MatF::full(n, m, 1.0);
        let cfg = PsoConfig { seed: g.rng().next_u64(), ..Default::default() };
        let f = PsoMatcher::new(cfg).run(&mask, &q, &gg).matched();
        let z = QuantizedMatcher::new(cfg).run(&mask, &q, &gg).matched();
        // both include the Ullmann repair, so both should match planted
        // instances; tolerate single-sided misses only if float missed too
        if z != f && f {
            return Err("quantized matcher lost a float-found embedding".into());
        }
        Ok(())
    });
}
