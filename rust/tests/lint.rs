//! Tier-1 gate for `immsched-lint`: the live tree must be clean, and
//! every rule must fire on a violating fixture and stay quiet on the
//! clean / pragma-suppressed variants.  All fixtures are raw strings —
//! the scrubbing lexer blanks string literals, so they are invisible
//! when the linter walks this very file.

use std::path::Path;

use immsched::lint::{
    lint_source, lint_tree, Finding, BAD_PRAGMA, NO_FLOAT_UNWRAP_ORD, NO_HASH_ITER_DETERMINISM,
    NO_LOSSY_WIRE_CAST, NO_PANIC_TRANSPORT, NO_UNBOUNDED_RETRY, NO_WALLCLOCK_CORE,
    OBS_CLOCK_DISCIPLINE, UNUSED_PRAGMA,
};

fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------------------
// the live tree (tier-1: the whole point of the linter)
// ---------------------------------------------------------------------------

#[test]
fn live_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_tree(root).expect("walking the crate sources");
    assert!(
        report.files_scanned > 40,
        "only {} files scanned — the walk missed src/tests/benches",
        report.files_scanned
    );
    let lines: Vec<String> = report.findings.iter().map(Finding::display_line).collect();
    assert!(report.is_clean(), "the tree must stay lint-clean; findings:\n{}", lines.join("\n"));
}

#[test]
fn report_json_is_machine_readable() {
    let findings = lint_source(
        "src/matcher/fixture.rs",
        r#"use std::collections::HashMap;"#,
    );
    assert!(!findings.is_empty());
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_tree(root).expect("walking the crate sources");
    let doc = immsched::util::json::Json::parse(&report.to_json().render())
        .expect("report must render as valid JSON");
    assert_eq!(
        doc.get("schema").and_then(immsched::util::json::Json::as_str),
        Some("immsched.lint/v1")
    );
    assert!(doc.get("findings").is_some());
}

// ---------------------------------------------------------------------------
// rule 1: no-float-unwrap-ord (applies everywhere)
// ---------------------------------------------------------------------------

#[test]
fn float_unwrap_ord_fires_on_both_forms() {
    let unwrapped = r#"
fn worst(a: f64, b: f64) -> std::cmp::Ordering {
    a.partial_cmp(&b).unwrap()
}
"#;
    let found = lint_source("src/util/fixture.rs", unwrapped);
    assert_eq!(rules_of(&found), vec![NO_FLOAT_UNWRAP_ORD], "{found:?}");

    let comparator = r#"
fn order(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.partial_cmp(b).expect("comparable"));
}
"#;
    let found = lint_source("src/util/fixture.rs", comparator);
    assert!(
        found.iter().all(|f| f.rule == NO_FLOAT_UNWRAP_ORD) && !found.is_empty(),
        "{found:?}"
    );

    // the rule has no test exemption: a panicking comparator in a test
    // aborts the test process just the same
    let in_tests = lint_source("tests/fixture.rs", unwrapped);
    assert_eq!(rules_of(&in_tests), vec![NO_FLOAT_UNWRAP_ORD]);
}

#[test]
fn float_total_cmp_and_trait_impls_are_clean() {
    let ok = r#"
fn order(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.total_cmp(b));
}
impl PartialOrd for Thing {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
"#;
    assert!(lint_source("src/util/fixture.rs", ok).is_empty());
}

// ---------------------------------------------------------------------------
// rule 2: no-hash-iter-determinism (deterministic modules only)
// ---------------------------------------------------------------------------

#[test]
fn hash_containers_flagged_only_in_deterministic_modules() {
    let hashy = r#"
use std::collections::{HashMap, HashSet};
fn table() -> HashMap<u32, f32> { HashMap::new() }
"#;
    for path in
        ["src/matcher/fixture.rs", "src/graph/fixture.rs", "src/cluster/wire.rs"]
    {
        let found = lint_source(path, hashy);
        assert!(
            found.iter().all(|f| f.rule == NO_HASH_ITER_DETERMINISM) && !found.is_empty(),
            "{path}: {found:?}"
        );
    }
    // outside the deterministic scope the same source is fine
    assert!(lint_source("src/accel/fixture.rs", hashy).is_empty());
    assert!(lint_source("tests/fixture.rs", hashy).is_empty());

    let ordered = r#"
use std::collections::{BTreeMap, BTreeSet};
fn table() -> BTreeMap<u32, f32> { BTreeMap::new() }
"#;
    assert!(lint_source("src/matcher/fixture.rs", ordered).is_empty());
}

// ---------------------------------------------------------------------------
// rule 3: no-wallclock-core (everywhere except service/driver edges)
// ---------------------------------------------------------------------------

#[test]
fn wallclock_flagged_in_core_but_not_at_the_boundary() {
    let clocky = r#"
fn stamp() -> f64 {
    let t = std::time::Instant::now();
    t.elapsed().as_secs_f64()
}
"#;
    let found = lint_source("src/scheduler/fixture.rs", clocky);
    assert_eq!(rules_of(&found), vec![NO_WALLCLOCK_CORE], "{found:?}");

    let systime = r#"use std::time::SystemTime;"#;
    let found = lint_source("src/matcher/fixture.rs", systime);
    assert_eq!(rules_of(&found), vec![NO_WALLCLOCK_CORE]);

    // boundary modules own the host clock legitimately
    for path in ["src/bin/fixture.rs", "benches/fixture.rs", "src/coordinator/service.rs"] {
        assert!(lint_source(path, clocky).is_empty(), "{path} is a clock boundary");
    }
    // `Instant` as a type (a deadline anchor passed in) is fine anywhere
    let typed = r#"fn anchor(base: std::time::Instant) -> std::time::Instant { base }"#;
    assert!(lint_source("src/coordinator/fixture.rs", typed).is_empty());
}

// ---------------------------------------------------------------------------
// rule 4: no-panic-transport (cluster wire/transport, non-test code)
// ---------------------------------------------------------------------------

#[test]
fn panic_paths_flagged_in_transport_modules() {
    let panicky = r#"
fn route(frames: &Vec<u8>, i: usize) -> u8 {
    let head = frames[i];
    let tail = frames.last().unwrap();
    if head != *tail { panic!("torn frame"); }
    head
}
"#;
    let found = lint_source("src/cluster/transport.rs", panicky);
    assert_eq!(found.len(), 3, "indexing + unwrap + panic!: {found:?}");
    assert!(found.iter().all(|f| f.rule == NO_PANIC_TRANSPORT));

    // the same code is allowed outside the transport boundary…
    assert!(lint_source("src/scheduler/fixture.rs", panicky).is_empty());
    // …and inside a #[cfg(test)] module of a transport file
    let tested = r#"
fn shift(x: u64) -> u64 { x >> 1 }
#[cfg(test)]
mod tests {
    #[test]
    fn round_trip() {
        let v = vec![1u8];
        assert_eq!(v[0], super::shift(2) as u8);
    }
}
"#;
    assert!(lint_source("src/cluster/transport.rs", tested).is_empty());
}

#[test]
fn non_panicking_transport_idioms_are_clean() {
    let ok = r#"
fn route(frames: &Vec<u8>, i: usize) -> Option<u8> {
    let head = frames.get(i)?;
    let fallback = frames.first().copied().unwrap_or(0);
    Some(head.wrapping_add(fallback))
}
"#;
    assert!(lint_source("src/cluster/wire.rs", ok).is_empty());
}

// ---------------------------------------------------------------------------
// rule 5: no-lossy-wire-cast (cluster wire only, tests included)
// ---------------------------------------------------------------------------

#[test]
fn bare_numeric_casts_flagged_in_wire() {
    let casty = r#"
fn encode(len: usize) -> u32 {
    len as u32
}
"#;
    let found = lint_source("src/cluster/wire.rs", casty);
    assert_eq!(rules_of(&found), vec![NO_LOSSY_WIRE_CAST], "{found:?}");
    // elsewhere a numeric cast is an accepted idiom
    assert!(lint_source("src/cluster/transport.rs", casty).is_empty());

    let checked = r#"
fn encode(len: usize) -> anyhow::Result<u32> {
    Ok(u32::try_from(len)?)
}
fn rename(x: ThisKind) -> f64 { x.as_f64() }
"#;
    assert!(lint_source("src/cluster/wire.rs", checked).is_empty());
}

// ---------------------------------------------------------------------------
// rule 6: no-unbounded-retry (fault-recovery modules, non-test code)
// ---------------------------------------------------------------------------

#[test]
fn unbounded_loops_flagged_in_fault_recovery_modules() {
    let spinny = r#"
fn redial(mut attempt: u32) -> u32 {
    loop {
        attempt = attempt.wrapping_add(1);
        if attempt == 0 { break; }
    }
    attempt
}
fn drain_backlog(mut backlog: u32) {
    while backlog > 0 {
        backlog = backlog.saturating_sub(1);
    }
}
"#;
    for path in ["src/cluster/supervise.rs", "src/cluster/chaos.rs"] {
        let found = lint_source(path, spinny);
        assert_eq!(found.len(), 2, "{path}: loop + while both spin blind: {found:?}");
        assert!(found.iter().all(|f| f.rule == NO_UNBOUNDED_RETRY));
    }
    // outside the fault-recovery scope the same source is fine
    assert!(lint_source("src/cluster/driver.rs", spinny).is_empty());
    assert!(lint_source("src/scheduler/fixture.rs", spinny).is_empty());
}

#[test]
fn bounded_pragmad_and_test_retries_are_clean() {
    // a bound-signalling identifier in the condition or body is the proof
    let bounded = r#"
fn redial(mut attempt: u32, max_replays: u32) -> u32 {
    while attempt < max_replays {
        attempt += 1;
    }
    attempt
}
fn backoff(mut tries: u32, budget: u32) -> u32 {
    loop {
        if tries >= budget { return tries; }
        tries += 1;
    }
}
"#;
    assert!(lint_source("src/cluster/supervise.rs", bounded).is_empty());

    // a justified pragma carries the termination argument instead
    let pledged = r#"
fn pump(stop: &std::sync::atomic::AtomicBool) {
    // lint:allow(no-unbounded-retry): runs until the owner flips the stop flag
    loop {
        if stop.load(std::sync::atomic::Ordering::Relaxed) { return; }
    }
}
"#;
    assert!(lint_source("src/cluster/supervise.rs", pledged).is_empty());

    // test code spins freely — a hung test is the harness's problem
    let in_tests = r#"
fn shift(x: u64) -> u64 { x >> 1 }
#[cfg(test)]
mod tests {
    #[test]
    fn spin() {
        let mut x = 4u64;
        loop {
            x = super::shift(x);
            if x == 0 { break; }
        }
    }
}
"#;
    assert!(lint_source("src/cluster/chaos.rs", in_tests).is_empty());
}

// ---------------------------------------------------------------------------
// the cluster::net subtree: transport + retry + wallclock scopes
// ---------------------------------------------------------------------------

#[test]
fn net_subtree_is_inside_the_panic_transport_boundary() {
    let panicky = r#"
fn route(frames: &Vec<u8>, i: usize) -> u8 {
    let head = frames[i];
    let tail = frames.last().unwrap();
    if head != *tail { panic!("torn frame"); }
    head
}
"#;
    // any file under src/cluster/net/ is transport code — the socket
    // subsystem must degrade to errors, never panic a serving process
    for path in ["src/cluster/net/socket.rs", "src/cluster/net/deep/fixture.rs"] {
        let found = lint_source(path, panicky);
        assert_eq!(found.len(), 3, "{path}: indexing + unwrap + panic!: {found:?}");
        assert!(found.iter().all(|f| f.rule == NO_PANIC_TRANSPORT), "{found:?}");
    }
}

#[test]
fn net_subtree_accept_and_redial_loops_must_be_bounded() {
    // an accept/heartbeat loop with no bound word and no pragma spins blind
    let spinny = r#"
fn accept_loop(pending: &mut u32) {
    loop {
        *pending = pending.wrapping_add(1);
        if *pending == 0 { break; }
    }
}
"#;
    let found = lint_source("src/cluster/net/registry_fixture.rs", spinny);
    assert_eq!(rules_of(&found), vec![NO_UNBOUNDED_RETRY], "{found:?}");

    // the real redial shape: the budget identifier is the proof
    let bounded = r#"
fn redial(mut attempt: u32, max_redials: u32) -> u32 {
    while attempt < max_redials {
        attempt = attempt.saturating_add(1);
    }
    attempt
}
"#;
    assert!(lint_source("src/cluster/net/socket_fixture.rs", bounded).is_empty());
}

#[test]
fn net_subtree_owns_the_host_clock() {
    // heartbeat windows and reconnect backoff legitimately read the
    // host clock — net/ sits on the wallclock boundary like the
    // transport layer it extends
    let clocky = r#"
fn age() -> f64 {
    let t = std::time::Instant::now();
    t.elapsed().as_secs_f64()
}
"#;
    assert!(lint_source("src/cluster/net/registry_fixture.rs", clocky).is_empty());
}

// ---------------------------------------------------------------------------
// the cluster::experiment subtree: determinism + retry + wallclock scopes
// ---------------------------------------------------------------------------

#[test]
fn experiment_subtree_joins_the_determinism_scope() {
    // a hash container anywhere in the harness would let process-random
    // iteration order reach the campaign summary bytes
    let hashy = r#"
use std::collections::HashMap;
fn tally() -> HashMap<u32, f64> { HashMap::new() }
"#;
    for path in ["src/cluster/experiment/model.rs", "src/cluster/experiment/deep/fixture.rs"] {
        let found = lint_source(path, hashy);
        assert!(
            found.iter().all(|f| f.rule == NO_HASH_ITER_DETERMINISM) && !found.is_empty(),
            "{path}: {found:?}"
        );
    }
}

#[test]
fn experiment_subtree_must_not_read_the_wall_clock() {
    // campaign numbers must be a pure function of (grid, seed): the
    // harness is NOT on the wallclock boundary, unlike the live driver
    let clocky = r#"
fn stamp() -> f64 {
    let t = std::time::Instant::now();
    t.elapsed().as_secs_f64()
}
"#;
    let found = lint_source("src/cluster/experiment/model_fixture.rs", clocky);
    assert_eq!(rules_of(&found), vec![NO_WALLCLOCK_CORE], "{found:?}");
}

#[test]
fn experiment_subtree_event_loops_must_be_bounded() {
    // an event/claim loop with no bound word spins a campaign forever
    let spinny = r#"
fn drain(mut backlog: u32) {
    loop {
        backlog = backlog.wrapping_add(1);
        if backlog == 0 { break; }
    }
}
"#;
    let found = lint_source("src/cluster/experiment/replicate_fixture.rs", spinny);
    assert_eq!(rules_of(&found), vec![NO_UNBOUNDED_RETRY], "{found:?}");

    // the real shapes: step_budget / job_cap identifiers are the proof
    let bounded = r#"
fn run(mut step_budget: u64) -> bool {
    loop {
        if step_budget == 0 { return false; }
        step_budget -= 1;
    }
}
fn claim(next: &mut usize, job_cap: usize) {
    while *next < job_cap {
        *next += 1;
    }
}
"#;
    assert!(lint_source("src/cluster/experiment/model_fixture.rs", bounded).is_empty());
}

// ---------------------------------------------------------------------------
// rule 7: obs-clock-discipline (src/obs/ minus the clock seam itself)
// ---------------------------------------------------------------------------

#[test]
fn obs_wallclock_trips_both_the_core_and_clock_discipline_rules() {
    let clocky = r#"
fn stamp() -> u64 {
    let t = std::time::Instant::now();
    t.elapsed().as_nanos() as u64
}
"#;
    // an obs/ file (other than clock.rs) reading the wall clock is both
    // unreplayable (rule 3) and a clock-seam bypass (rule 7)
    for path in ["src/obs/trace.rs", "src/obs/recorder.rs", "src/obs/fixture.rs"] {
        let mut rules = rules_of(&lint_source(path, clocky));
        rules.sort_unstable();
        assert_eq!(rules, vec![NO_WALLCLOCK_CORE, OBS_CLOCK_DISCIPLINE], "{path}");
    }
    let systime = r#"use std::time::SystemTime;"#;
    let mut rules = rules_of(&lint_source("src/obs/metrics.rs", systime));
    rules.sort_unstable();
    assert_eq!(rules, vec![NO_WALLCLOCK_CORE, OBS_CLOCK_DISCIPLINE]);
}

#[test]
fn obs_clock_seam_owns_the_host_clock() {
    let clocky = r#"
fn anchor() -> std::time::Instant {
    std::time::Instant::now()
}
"#;
    // clock.rs IS the seam: on the wallclock boundary and outside the
    // discipline scope, so neither rule fires there
    assert!(lint_source("src/obs/clock.rs", clocky).is_empty());
}

#[test]
fn obs_subtree_joins_the_panic_and_determinism_scopes() {
    let panicky = r#"
fn render(fields: &Vec<u8>, i: usize) -> u8 {
    fields[i]
}
"#;
    let found = lint_source("src/obs/metrics.rs", panicky);
    assert_eq!(rules_of(&found), vec![NO_PANIC_TRANSPORT], "{found:?}");

    let hashy = r#"use std::collections::HashMap;"#;
    let found = lint_source("src/obs/trace.rs", hashy);
    assert_eq!(rules_of(&found), vec![NO_HASH_ITER_DETERMINISM], "{found:?}");
}

#[test]
fn obs_clock_discipline_pragma_is_honored() {
    let pledged = r#"
fn stamp() -> u64 {
    // lint:allow(obs-clock-discipline): fixture proves the pragma routes to rule 7
    // lint:allow(no-wallclock-core): same site, the stacked rule 3 finding
    let t = std::time::Instant::now();
    t.elapsed().as_nanos() as u64
}
"#;
    assert!(lint_source("src/obs/fixture.rs", pledged).is_empty());
}

// ---------------------------------------------------------------------------
// pragmas
// ---------------------------------------------------------------------------

#[test]
fn justified_pragma_suppresses_same_line_and_above() {
    let same_line = r#"
use std::collections::HashMap; // lint:allow(no-hash-iter-determinism): fixture proves same-line coverage
"#;
    assert!(lint_source("src/matcher/fixture.rs", same_line).is_empty());

    let above = r#"
// lint:allow(no-hash-iter-determinism): fixture proves the standalone form,
// including trailing comment lines between the pragma and the code
use std::collections::HashMap;
"#;
    assert!(lint_source("src/matcher/fixture.rs", above).is_empty());
}

#[test]
fn pragma_does_not_leak_past_the_first_code_line() {
    let leaky = r#"
// lint:allow(no-hash-iter-determinism): covers only the line below
use std::collections::HashMap;
use std::collections::HashSet;
"#;
    let found = lint_source("src/matcher/fixture.rs", leaky);
    assert_eq!(rules_of(&found), vec![NO_HASH_ITER_DETERMINISM], "{found:?}");
    assert_eq!(found[0].line, 4, "the second hash container is NOT covered");
}

#[test]
fn unjustified_or_unknown_pragmas_are_findings_themselves() {
    let bare = r#"
// lint:allow(no-hash-iter-determinism)
use std::collections::HashMap;
"#;
    let found = lint_source("src/matcher/fixture.rs", bare);
    // the naked pragma suppresses nothing, so the finding survives too
    let mut rules = rules_of(&found);
    rules.sort_unstable();
    assert_eq!(rules, vec![BAD_PRAGMA, NO_HASH_ITER_DETERMINISM], "{found:?}");

    let unknown = r#"
// lint:allow(no-such-rule): long enough justification text
fn fine() {}
"#;
    let found = lint_source("src/matcher/fixture.rs", unknown);
    assert_eq!(rules_of(&found), vec![BAD_PRAGMA]);
}

#[test]
fn doc_comments_only_quote_pragmas_never_carry_them() {
    // documentation that *shows* the pragma syntax must neither
    // suppress findings nor be reported as a bad/unused pragma
    let documented = r#"
//! Suppress with `// lint:allow(no-wallclock-core): why it is safe`.

/// Such as `// lint:allow(not-a-rule)` — quoted, not live.
fn pure(x: u64) -> u64 { x + 1 }
"#;
    assert!(lint_source("src/scheduler/fixture.rs", documented).is_empty());
}

#[test]
fn unused_justified_pragma_is_reported() {
    let stale = r#"
// lint:allow(no-wallclock-core): this used to guard an Instant call
fn pure(x: u64) -> u64 { x + 1 }
"#;
    let found = lint_source("src/scheduler/fixture.rs", stale);
    assert_eq!(rules_of(&found), vec![UNUSED_PRAGMA], "{found:?}");
}

// ---------------------------------------------------------------------------
// the scrubbing lexer: quoted counter-examples never fire
// ---------------------------------------------------------------------------

#[test]
fn comments_strings_and_raw_strings_are_invisible() {
    let quoted = r##"
// partial_cmp(&b).unwrap() in a comment is fine
/* and HashMap in a block comment, even /* nested */ ones */
fn doc() -> &'static str {
    let a = "std::time::Instant::now() quoted";
    let b = r#"v.sort_by(|a, b| a.partial_cmp(b).unwrap())"#;
    let c = b"HashMap as bytes";
    if a.len() + b.len() + c.len() > 0 { a } else { b }
}
"##;
    assert!(lint_source("src/matcher/fixture.rs", quoted).is_empty());
}

#[test]
fn char_literals_and_lifetimes_do_not_desync_the_lexer() {
    let tricky = r#"
fn first<'a>(s: &'a str) -> Option<&'a str> {
    let quote = '"';
    let escaped = '\'';
    let _ = (quote, escaped);
    s.split(' ').next()
}
use std::collections::HashMap;
"#;
    // if the lexer mistook a lifetime for an open char literal it would
    // blank the rest of the file and miss the real violation below
    let found = lint_source("src/matcher/fixture.rs", tricky);
    assert_eq!(rules_of(&found), vec![NO_HASH_ITER_DETERMINISM], "{found:?}");
    assert_eq!(found[0].line, 8);
}
