//! Cluster-serving behavior: routing conservation (every submitted id
//! is answered exactly once across shards — done, shed, or
//! cancelled-with-snapshot), cross-shard warm-start resume, the
//! epoch-quota slicing loop through the public service API, and the
//! transport-equivalence acceptance: identical dispositions on
//! in-process and out-of-process shards, with bit-identical resume
//! across the process boundary.
//!
//! The out-of-process tests spawn the real `immsched shard-worker`
//! binary (cargo builds it for integration tests and exposes the path
//! via `CARGO_BIN_EXE_immsched`).

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use immsched::cluster::transport::{
    FrameFault, InProcessShard, ProcessShard, ShardTransport, TransportConfig,
};
use immsched::cluster::{
    ChaosFault, ChaosSchedule, ClusterConfig, DeadlineAware, FaultInjectingTransport,
    LeastQueueDepth, MatchCluster, RoundRobin, SupervisedFleet, SupervisorConfig,
};
use immsched::coordinator::{
    MatchPath, MatchProblem, MatchService, RequestId, ServiceConfig, SubmitOptions,
};
use immsched::graph::{gen_chain, NodeKind};
use immsched::matcher::PsoConfig;
use immsched::scheduler::Priority;
use immsched::util::MatF;

/// The worker binary the out-of-process tests spawn.
const WORKER_BIN: &str = env!("CARGO_BIN_EXE_immsched");

fn chain_problem(n: usize, m: usize) -> MatchProblem {
    let qd = gen_chain(n, NodeKind::Compute);
    let gd = gen_chain(m, NodeKind::Universal);
    MatchProblem::from_dags(&qd, &gd)
}

/// Full mask, no embedding (3-fan-out star into a chain): the episode
/// runs its whole epoch budget unless preempted/sliced.
fn infeasible_star_problem() -> MatchProblem {
    let mut q = MatF::zeros(4, 4);
    q[(0, 1)] = 1.0;
    q[(0, 2)] = 1.0;
    q[(0, 3)] = 1.0;
    let gd = gen_chain(8, NodeKind::Universal);
    MatchProblem::from_dense(&MatF::full(4, 8, 1.0), &q, &gd.adjacency())
}

/// Routing conservation: across a mixed batch (serveable, already
/// expired, cancelled-in-flight), every cluster-assigned id comes back
/// exactly once, and cancelled episodes leave their snapshots behind.
#[test]
fn every_submitted_id_is_answered_exactly_once_across_shards() {
    let cluster = MatchCluster::spawn(
        ClusterConfig {
            shards: 3,
            pso: PsoConfig { seed: 17, epochs: 20_000, repair_budget: 1_000, ..Default::default() },
            ..Default::default()
        },
        Box::<RoundRobin>::default(),
    )
    .unwrap();

    let mut tickets = Vec::new();
    // serveable requests
    for _ in 0..6 {
        tickets.push(cluster.submit(chain_problem(4, 8), Priority::Normal, Some(60.0)).unwrap());
    }
    // dead-on-arrival requests (negative SLO budget → expired deadline)
    for _ in 0..3 {
        tickets.push(cluster.submit(chain_problem(4, 8), Priority::Normal, Some(-1.0)).unwrap());
    }
    // long-running infeasible episodes, cancelled by the caller
    for _ in 0..3 {
        let t = cluster.submit(infeasible_star_problem(), Priority::Background, None).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        t.cancel();
        tickets.push(t);
    }

    let submitted = tickets.len();
    let mut ids = Vec::new();
    let (mut done, mut shed, mut cancelled) = (0usize, 0usize, 0usize);
    for t in tickets {
        let id = t.id;
        let resp = t.wait().expect("every ticket answers");
        assert_eq!(resp.id, id, "response must echo the cluster id");
        ids.push(resp.id);
        match resp.path {
            MatchPath::Shed => shed += 1,
            MatchPath::Cancelled => {
                cancelled += 1;
                // a cancelled in-flight episode leaves a resumable
                // snapshot in the store (queued-cancel leaves none)
                if resp.snapshot.is_some() {
                    assert!(
                        cluster.resume_store().contains(resp.id),
                        "cancelled episode's snapshot must be persisted"
                    );
                }
            }
            _ => done += 1,
        }
    }
    ids.sort_unstable();
    let unique = ids.len();
    ids.dedup();
    assert_eq!(ids.len(), unique, "duplicate responses for one id");
    assert_eq!(done + shed + cancelled, submitted, "lost requests");
    assert_eq!(done, 6);
    assert_eq!(shed, 3);
    assert_eq!(cancelled, 3);
}

/// Cross-shard migration of a warm start: an episode sliced by the
/// epoch quota on service A resumes on service B (a different
/// controller, different thread) and finishes exactly the remaining
/// epochs.
#[test]
fn quota_sliced_episode_resumes_on_another_shard() {
    let epochs = 40usize;
    let pso = PsoConfig { seed: 23, epochs, repair_budget: 1_000, ..Default::default() };
    let sliced = MatchService::spawn_configured(
        ServiceConfig { epoch_quota: Some(15), ..Default::default() },
        pso,
    )
    .unwrap();
    let full = MatchService::spawn_configured(ServiceConfig::default(), pso).unwrap();

    let first = sliced
        .submit_with(
            infeasible_star_problem(),
            Priority::Normal,
            None,
            SubmitOptions { id: Some(77), ..Default::default() },
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(first.path, MatchPath::Cancelled);
    assert_eq!(first.epochs_run, 15, "quota slice must stop at the barrier");
    let snapshot = first.snapshot.expect("sliced episode must hand back its swarm state");

    let second = full
        .submit_with(
            infeasible_star_problem(),
            Priority::Normal,
            None,
            SubmitOptions { id: Some(77), resume: Some(snapshot) },
        )
        .unwrap()
        .wait()
        .unwrap();
    assert!(second.resumed, "migrated episode must report the resumed signal");
    assert_ne!(second.path, MatchPath::Cancelled);
    assert_eq!(
        first.epochs_run + second.epochs_run,
        epochs,
        "resume must complete exactly the remaining epochs"
    );
    assert_eq!(full.stats().controller.resumed, 1);
}

/// The cluster's own resubmit loop: repeated quota slices walk an
/// episode to completion across resubmissions, never re-exploring
/// burned epochs.
#[test]
fn cluster_resubmit_walks_a_sliced_episode_to_completion() {
    let epochs = 30usize;
    let cluster = MatchCluster::spawn(
        ClusterConfig {
            shards: 2,
            service: ServiceConfig { epoch_quota: Some(8), ..Default::default() },
            pso: PsoConfig { seed: 31, epochs, repair_budget: 1_000, ..Default::default() },
            ..Default::default()
        },
        Box::new(LeastQueueDepth),
    )
    .unwrap();

    let problem = infeasible_star_problem();
    let first = cluster.submit(problem.clone(), Priority::Normal, None).unwrap();
    let id = first.id;
    let mut resp = first.wait().unwrap();
    let mut total_epochs = resp.epochs_run;
    let mut hops = 0;
    while resp.path == MatchPath::Cancelled {
        hops += 1;
        assert!(hops <= 10, "sliced episode did not converge");
        resp = cluster
            .resubmit(id, problem.clone(), Priority::Normal, None)
            .unwrap()
            .wait()
            .unwrap();
        total_epochs += resp.epochs_run;
    }
    assert!(hops >= 2, "quota 8 over {epochs} epochs must slice repeatedly");
    assert!(resp.resumed, "final hop must be a warm start");
    assert_eq!(total_epochs, epochs, "slices must add up to exactly one cold solve");
    let stats = cluster.stats();
    assert!(stats.resumes() >= hops as u64, "every hop after the first warm-starts");
    assert_eq!(stats.resume.saved, hops as u64);
    assert_eq!(stats.resume.taken, hops as u64);
}

/// Shedding must never destroy persisted progress: a resubmission whose
/// admission sheds it (here: expired deadline) hands the warm-start
/// snapshot back in the `Shed` response, so the cluster re-stashes it
/// and a later resubmission still warm-starts.
#[test]
fn shed_resubmission_returns_the_snapshot_instead_of_dropping_it() {
    let epochs = 24usize;
    let pso = PsoConfig { seed: 53, epochs, repair_budget: 1_000, ..Default::default() };
    let sliced = MatchService::spawn_configured(
        ServiceConfig { epoch_quota: Some(10), ..Default::default() },
        pso,
    )
    .unwrap();
    let first = sliced
        .submit_with(
            infeasible_star_problem(),
            Priority::Normal,
            None,
            SubmitOptions { id: Some(5), ..Default::default() },
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(first.path, MatchPath::Cancelled);
    let snapshot = first.snapshot.expect("sliced episode yields a snapshot");

    // resubmit with the snapshot but an already-expired deadline: shed
    let shed = sliced
        .submit_with(
            infeasible_star_problem(),
            Priority::Normal,
            Some(-1.0),
            SubmitOptions { id: Some(5), resume: Some(snapshot) },
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(shed.path, MatchPath::Shed);
    let recovered = shed.snapshot.expect("shed must hand the unused snapshot back");
    assert_eq!(recovered.epochs_done, 10, "snapshot must survive the shed untouched");

    // the recovered snapshot still warm-starts a live resubmission
    let done = sliced
        .submit_with(
            infeasible_star_problem(),
            Priority::Normal,
            None,
            SubmitOptions { id: Some(5), resume: Some(recovered) },
        )
        .unwrap()
        .wait()
        .unwrap();
    assert!(done.resumed, "recovered snapshot must warm-start");
    assert_eq!(done.epochs_run, 10, "second slice resumes at epoch 10, not epoch 0");
    assert_eq!(done.snapshot.expect("re-sliced").epochs_done, 20);
}

/// One request's final disposition after walking quota slices to
/// completion — everything that must be transport-invariant.
#[derive(Debug, PartialEq)]
struct Disposition {
    path: &'static str,
    epochs_total: usize,
    final_epochs: usize,
    resumed: bool,
    hops: u32,
    mappings: Vec<Vec<Option<usize>>>,
    best_fitness_bits: u32,
}

/// Submit a fixed request sequence (feasible chains interleaved with
/// quota-sliced infeasible stars), resubmitting cancelled episodes from
/// their persisted snapshots until each completes, and record every
/// final disposition in submission order.
fn run_disposition_walk(cluster: &MatchCluster) -> Vec<Disposition> {
    let mut problems: Vec<MatchProblem> = Vec::new();
    for i in 0..8 {
        if i % 4 == 3 {
            problems.push(infeasible_star_problem());
        } else {
            problems.push(chain_problem(4, 8));
        }
    }
    let mut out = Vec::new();
    for problem in problems {
        // sequential submit→settle keeps the walk timing-independent:
        // dispositions must depend on the transport never, on
        // concurrency races never, only on (seed, policy, quota)
        let ticket = cluster.submit(problem.clone(), Priority::Normal, None).unwrap();
        let id = ticket.id;
        let mut resp = ticket.wait().unwrap();
        let mut epochs_total = resp.epochs_run;
        let mut hops = 0u32;
        while resp.path == MatchPath::Cancelled {
            hops += 1;
            assert!(hops <= 16, "sliced episode did not converge");
            resp = cluster
                .resubmit(id, problem.clone(), Priority::Normal, None)
                .unwrap()
                .wait()
                .unwrap();
            epochs_total += resp.epochs_run;
        }
        out.push(Disposition {
            path: resp.path.name(),
            epochs_total,
            final_epochs: resp.epochs_run,
            resumed: resp.resumed,
            hops,
            mappings: resp.mappings,
            best_fitness_bits: resp.best_fitness.to_bits(),
        });
    }
    out
}

fn walk_config() -> ClusterConfig {
    ClusterConfig {
        shards: 2,
        service: ServiceConfig { epoch_quota: Some(8), ..Default::default() },
        pso: PsoConfig { seed: 61, epochs: 20, repair_budget: 1_000, ..Default::default() },
        ..Default::default()
    }
}

/// Acceptance: a cluster run with identical seed, request sequence and
/// route policy produces the *same* per-request dispositions (paths,
/// epoch totals, resume signals, mappings, fitness bits) whether the
/// shards are in-process service threads or out-of-process
/// `shard-worker` children behind the wire protocol.
#[test]
fn in_process_and_process_transports_produce_identical_dispositions() {
    let in_proc =
        MatchCluster::spawn(walk_config(), Box::<RoundRobin>::default()).unwrap();
    let in_proc_walk = run_disposition_walk(&in_proc);

    let out_proc = MatchCluster::spawn_process_shards_at(
        Path::new(WORKER_BIN),
        walk_config(),
        Box::<RoundRobin>::default(),
    )
    .unwrap();
    assert_eq!(out_proc.transport_kinds(), vec!["process"; 2]);
    let out_proc_walk = run_disposition_walk(&out_proc);
    out_proc.drain().expect("workers drain cleanly");

    assert_eq!(
        in_proc_walk, out_proc_walk,
        "dispositions must not depend on the transport"
    );
    // the walk exercised the interesting paths, not just happy serves
    assert!(in_proc_walk.iter().any(|d| d.hops >= 2), "no quota slicing happened");
    assert!(in_proc_walk.iter().any(|d| d.resumed), "no warm start happened");
    assert!(in_proc_walk.iter().any(|d| !d.mappings.is_empty()), "nothing matched");
}

/// Acceptance: a snapshot migrated across a process boundary resumes
/// bit-identically to a same-process resume — same epochs, same
/// mappings, same fitness bits, same follow-up snapshot.
#[test]
fn snapshot_migrated_across_process_boundary_resumes_bit_identically() {
    let epochs = 40usize;
    let pso = PsoConfig { seed: 23, epochs, repair_budget: 1_000, ..Default::default() };
    let sliced = MatchService::spawn_configured(
        ServiceConfig { epoch_quota: Some(15), ..Default::default() },
        pso,
    )
    .unwrap();
    let first = sliced
        .submit_with(
            infeasible_star_problem(),
            Priority::Normal,
            None,
            SubmitOptions { id: Some(9), ..Default::default() },
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(first.path, MatchPath::Cancelled);
    assert_eq!(first.epochs_run, 15);
    let snapshot = first.snapshot.expect("sliced episode yields a snapshot");

    // resume A: same process, fresh service
    let same_proc = MatchService::spawn_configured(ServiceConfig::default(), pso).unwrap();
    let resumed_here = same_proc
        .submit_with(
            infeasible_star_problem(),
            Priority::Normal,
            None,
            SubmitOptions { id: Some(9), resume: Some(snapshot.clone()) },
        )
        .unwrap()
        .wait()
        .unwrap();

    // resume B: the identical snapshot crosses the wire codec into a
    // shard-worker child process and resumes there
    let shard =
        ProcessShard::spawn_at(Path::new(WORKER_BIN), ServiceConfig::default(), pso).unwrap();
    let id: RequestId = 9;
    shard
        .submit(id, infeasible_star_problem(), Priority::Normal, None, Some(snapshot))
        .unwrap();
    let resumed_there = shard.wait_response(id).unwrap();
    shard.drain().expect("worker drains cleanly");

    assert!(resumed_here.resumed && resumed_there.resumed, "both must warm-start");
    assert_eq!(resumed_there.path, resumed_here.path);
    assert_eq!(resumed_there.epochs_run, resumed_here.epochs_run);
    assert_eq!(
        first.epochs_run + resumed_there.epochs_run,
        epochs,
        "migrated resume must complete exactly the remaining epochs"
    );
    assert_eq!(resumed_there.mappings, resumed_here.mappings);
    assert_eq!(
        resumed_there.best_fitness.to_bits(),
        resumed_here.best_fitness.to_bits(),
        "fitness must match to the bit"
    );
    match (&resumed_here.snapshot, &resumed_there.snapshot) {
        (None, None) => {}
        (Some(a), Some(b)) => assert_eq!(a, b, "follow-up snapshots must be bit-identical"),
        (a, b) => panic!("snapshot presence diverged: {:?} vs {:?}", a.is_some(), b.is_some()),
    }
}

/// A supervisor tuned for test cadences: fast heartbeat, short replay
/// backoff, a few extra replay attempts to ride out stale status
/// caches right after a kill.
fn fast_supervisor() -> SupervisorConfig {
    SupervisorConfig {
        heartbeat_interval: Duration::from_millis(10),
        backoff_base: Duration::from_millis(2),
        backoff_cap: Duration::from_millis(20),
        max_replays: 6,
        ..Default::default()
    }
}

/// Resubmit through the fleet, riding out the window where routing may
/// still steer onto a shard that just died (its cached status has not
/// expired yet — the cluster routes on a TTL'd view of shard health).
fn resubmit_insistently(fleet: &SupervisedFleet, id: RequestId, problem: &MatchProblem) {
    let mut attempts = 0;
    while let Err(e) = fleet.resubmit(id, problem.clone(), Priority::Normal, None) {
        attempts += 1;
        assert!(attempts < 200, "resubmit never found a live shard: {e:#}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Acceptance (tier-1): a worker killed mid-episode fails over onto the
/// surviving shard, warm-starting from the last persisted barrier, and
/// the epochs reported across every received slice add up to *exactly*
/// the uninterrupted budget — a crash costs at most the unpersisted
/// tail of one slice, never double-counts, never restarts silently.
#[test]
fn killed_worker_fails_over_and_conserves_the_epoch_budget() {
    let epochs = 40usize;
    let pso = PsoConfig { seed: 23, epochs, repair_budget: 1_000, ..Default::default() };
    let svc = ServiceConfig { epoch_quota: Some(15), ..Default::default() };
    let shards: Vec<Arc<ProcessShard>> = (0..2)
        .map(|_| Arc::new(ProcessShard::spawn_at(Path::new(WORKER_BIN), svc, pso).unwrap()))
        .collect();
    let transports: Vec<Arc<dyn ShardTransport>> =
        shards.iter().map(|s| Arc::clone(s) as Arc<dyn ShardTransport>).collect();
    let mut cluster =
        MatchCluster::with_transports(transports, Box::new(LeastQueueDepth), 64);
    // keep routing's view of a dead shard fresh — a long-lived stale
    // "healthy" cache entry would bounce replays off the corpse
    cluster.set_status_ttl(Duration::from_millis(5));
    let cluster = Arc::new(cluster);
    let fleet = SupervisedFleet::new(Arc::clone(&cluster), fast_supervisor());

    let problem = infeasible_star_problem();
    let id = fleet.submit(problem.clone(), Priority::Normal, None).unwrap();
    // kill the worker the request was routed to, mid-episode: the first
    // quota slice takes milliseconds, the abort lands in microseconds
    let victim = fleet.shard_of(id).expect("submitted request must be ticketed");
    shards[victim].abort();

    let mut resp = fleet.wait(id).unwrap();
    let mut total_epochs = resp.epochs_run;
    let mut hops = 0;
    while resp.path == MatchPath::Cancelled {
        hops += 1;
        assert!(hops <= 16, "episode did not converge after failover");
        resubmit_insistently(&fleet, id, &problem);
        resp = fleet.wait(id).unwrap();
        total_epochs += resp.epochs_run;
    }
    assert_ne!(resp.path, MatchPath::Shed, "two shards must absorb one worker death");
    assert!(resp.resumed, "the final slice must warm-start from a persisted barrier");
    assert_eq!(
        total_epochs, epochs,
        "epochs across the kill must add up to exactly one uninterrupted budget"
    );
    let failover = fleet.failover();
    assert!(failover.shards_failed >= 1, "the kill must be detected: {failover:?}");
    assert!(failover.replays >= 1, "the in-flight victim must be replayed: {failover:?}");
    assert_eq!(fleet.live_shards(), 1, "exactly one shard survives");
    // the survivor still drains cleanly (the fleet's own drain would
    // also try the corpse, which can no longer answer control traffic)
    drop(fleet);
    shards[1 - victim].drain().expect("survivor drains cleanly");
}

/// Satellite: when the *only* worker dies after a slice persisted its
/// barrier, replay exhausts against zero live capacity and the fleet
/// degrades to a shed answer — but the shed response hands the
/// warm-start snapshot back instead of destroying the progress.
#[test]
fn dead_worker_shed_hands_the_snapshot_back() {
    let pso = PsoConfig { seed: 53, epochs: 24, repair_budget: 1_000, ..Default::default() };
    let svc = ServiceConfig { epoch_quota: Some(10), ..Default::default() };
    let shard =
        Arc::new(ProcessShard::spawn_at(Path::new(WORKER_BIN), svc, pso).unwrap());
    let transports: Vec<Arc<dyn ShardTransport>> =
        vec![Arc::clone(&shard) as Arc<dyn ShardTransport>];
    let mut cluster =
        MatchCluster::with_transports(transports, Box::<RoundRobin>::default(), 64);
    cluster.set_status_ttl(Duration::from_millis(5));
    let fleet = SupervisedFleet::new(Arc::new(cluster), fast_supervisor());

    let problem = infeasible_star_problem();
    let id = fleet.submit(problem.clone(), Priority::Normal, None).unwrap();
    let first = fleet.wait(id).unwrap();
    assert_eq!(first.path, MatchPath::Cancelled, "quota 10 slices the 24-epoch episode");
    assert_eq!(first.epochs_run, 10);

    // resubmit the second slice, then kill the only worker before it
    // can answer — the child dies holding the in-flight request
    fleet.resubmit(id, problem, Priority::Normal, None).unwrap();
    shard.abort();

    let resp = fleet.wait(id).unwrap();
    assert_eq!(resp.path, MatchPath::Shed, "no live capacity left: degrade, don't hang");
    let snapshot = resp.snapshot.expect("shed must hand the warm-start snapshot back");
    assert_eq!(
        snapshot.epochs_done, 10,
        "the persisted barrier must survive the crash untouched"
    );
    assert!(fleet.failover().shed_at_floor >= 1);
}

/// What must be identical across two chaos runs with the same seeds
/// and schedules.
#[derive(Debug, PartialEq)]
struct ChaosRun {
    dispositions: Vec<(&'static str, usize, bool, u32)>,
    replays: u64,
    sheds: u64,
    injected: String,
}

/// Drive a fixed workload through a supervised fleet whose in-process
/// shards sit behind seeded fault injectors (a reply dropped on each
/// shard, a delay on the first submission), and record everything
/// observable about the outcome.
fn run_chaos_fleet(chaos_seed: u64) -> ChaosRun {
    let pso = PsoConfig { seed: 61, epochs: 20, repair_budget: 1_000, ..Default::default() };
    let svc = ServiceConfig::default();
    let schedules = [
        ChaosSchedule::default()
            .at(0, ChaosFault::Delay(Duration::from_millis(2)))
            .at(1, ChaosFault::DropReply),
        ChaosSchedule::default().at(2, ChaosFault::DropReply),
    ];
    let chaos: Vec<Arc<FaultInjectingTransport>> = schedules
        .iter()
        .enumerate()
        .map(|(shard, schedule)| {
            let inner: Arc<dyn ShardTransport> =
                Arc::new(InProcessShard::spawn(svc, pso).unwrap());
            Arc::new(FaultInjectingTransport::new(
                inner,
                schedule.clone(),
                chaos_seed ^ shard as u64,
            ))
        })
        .collect();
    let transports: Vec<Arc<dyn ShardTransport>> =
        chaos.iter().map(|c| Arc::clone(c) as Arc<dyn ShardTransport>).collect();
    let cluster = Arc::new(MatchCluster::with_transports(
        transports,
        Box::<RoundRobin>::default(),
        64,
    ));
    let fleet = SupervisedFleet::new(Arc::clone(&cluster), fast_supervisor());

    let mut dispositions = Vec::new();
    for i in 0..6 {
        let problem =
            if i % 2 == 1 { infeasible_star_problem() } else { chain_problem(4, 8) };
        let id = fleet.submit(problem.clone(), Priority::Normal, None).unwrap();
        let mut resp = fleet.wait(id).unwrap();
        let mut epochs_total = resp.epochs_run;
        let mut hops = 0u32;
        while resp.path == MatchPath::Cancelled {
            hops += 1;
            assert!(hops <= 16, "episode did not converge under chaos");
            fleet.resubmit(id, problem.clone(), Priority::Normal, None).unwrap();
            resp = fleet.wait(id).unwrap();
            epochs_total += resp.epochs_run;
        }
        dispositions.push((resp.path.name(), epochs_total, resp.resumed, hops));
    }
    let failover = fleet.failover();
    let injected =
        chaos.iter().map(|c| format!("{:?}", c.stats())).collect::<Vec<_>>().join(" | ");
    ChaosRun {
        dispositions,
        replays: failover.replays,
        sheds: failover.shed_at_floor,
        injected,
    }
}

/// Acceptance: chaos is deterministic — the same seeds and schedules
/// produce the same per-request dispositions, the same replay counts,
/// and the same injected-fault tallies on two independent runs.
#[test]
fn chaos_with_equal_seeds_and_schedules_is_deterministic() {
    let first = run_chaos_fleet(0xC0FFEE);
    let second = run_chaos_fleet(0xC0FFEE);
    assert_eq!(first, second, "chaos dispositions must be a pure function of the seed");
    assert!(first.replays >= 1, "the scheduled reply drops must force replays: {first:?}");
    assert_eq!(first.sheds, 0, "healthy shards absorb dropped replies without shedding");
    assert!(
        first.injected.contains("dropped_replies: 1"),
        "each shard must record its scheduled drop: {}",
        first.injected
    );
}

/// Satellite: the configurable control timeout bounds how long a
/// *wedged* (not dead) worker can stall a control round-trip.  A
/// truncated frame promises bytes that never arrive, wedging the
/// worker's reader mid-frame; with a short [`TransportConfig`] the
/// next status probe fails in well under the 30-second default.
#[test]
fn truncated_frame_wedges_within_the_configured_control_timeout() {
    let pso = PsoConfig { seed: 7, ..Default::default() };
    let tcfg = TransportConfig {
        control_timeout: Duration::from_millis(250),
        ..Default::default()
    };
    let shard = ProcessShard::spawn_at_with(
        Path::new(WORKER_BIN),
        ServiceConfig::default(),
        pso,
        tcfg,
    )
    .unwrap();
    shard.status().expect("a fresh worker answers control traffic");

    shard.inject_frame_fault(FrameFault::Truncated).unwrap();
    let probe_started = Instant::now();
    let probe = shard.status();
    let waited = probe_started.elapsed();
    assert!(probe.is_err(), "a wedged worker must fail the control round-trip");
    assert!(
        waited < Duration::from_secs(10),
        "the 250ms control timeout must bound detection, not the 30s default: {waited:?}"
    );
    shard.abort();
}

/// Deadline-aware routing preempts across shards: with every shard busy
/// on Background work, an urgent arrival lands on a shard whose victim
/// is Background and cancels it at the epoch barrier.
#[test]
fn deadline_aware_routing_preempts_weakest_shard() {
    let cluster = MatchCluster::spawn(
        ClusterConfig {
            shards: 2,
            pso: PsoConfig { seed: 41, epochs: 20_000, repair_budget: 1_000, ..Default::default() },
            ..Default::default()
        },
        Box::new(DeadlineAware),
    )
    .unwrap();

    let mut fillers = Vec::new();
    for shard in 0..2 {
        fillers.push(
            cluster
                .submit_to(shard, infeasible_star_problem(), Priority::Background, None)
                .unwrap(),
        );
    }
    for shard in 0..2 {
        let mut waited = 0;
        while cluster.views()[shard].in_flight != Some(Priority::Background) {
            std::thread::sleep(Duration::from_millis(2));
            waited += 1;
            assert!(waited < 5_000, "filler never started on shard {shard}");
        }
    }

    let urgent = cluster.submit(chain_problem(4, 8), Priority::Urgent, Some(30.0)).unwrap();
    let resp = urgent.wait().unwrap();
    assert!(resp.matched(), "urgent request must be served");

    // at least one filler was preempted by the routed urgent arrival;
    // cancel the rest to shut down promptly (a non-targeted filler may
    // legitimately have completed its bounded budget by now)
    let mut cancelled = 0;
    for f in fillers {
        f.cancel();
        let r = f.wait().unwrap();
        cancelled += usize::from(r.path == MatchPath::Cancelled);
    }
    assert!(cancelled >= 1, "no filler answered Cancelled");
    assert!(
        cluster.stats().preemptions() >= 1,
        "deadline-aware routing must have preempted a Background victim"
    );
}

/// Observability ordering: `views()` and `stats()` index shards in
/// ascending shard-id order on every call.  The cluster keys its
/// internal tables by ordered maps (`BTreeMap`), so two reads taken at
/// a quiet moment must agree exactly — a regression to hash-ordered
/// iteration would make this flap across processes.
#[test]
fn views_and_stats_report_shards_in_stable_ascending_order() {
    let cluster = MatchCluster::spawn(
        ClusterConfig {
            shards: 4,
            pso: PsoConfig { seed: 5, epochs: 10_000, repair_budget: 500, ..Default::default() },
            ..Default::default()
        },
        Box::<LeastQueueDepth>::default(),
    )
    .unwrap();

    let tickets: Vec<_> = (0..8)
        .map(|_| cluster.submit(chain_problem(4, 8), Priority::Normal, Some(60.0)).unwrap())
        .collect();
    for t in tickets {
        t.wait().expect("every ticket answers");
    }

    let ids: Vec<_> = cluster.views().iter().map(|v| v.shard).collect();
    assert_eq!(ids, vec![0, 1, 2, 3], "views must come back ascending by shard id");
    let again: Vec<_> = cluster.views().iter().map(|v| v.shard).collect();
    assert_eq!(ids, again, "view order must not change between reads");

    let stats = cluster.stats();
    assert_eq!(stats.shards.len(), 4, "one stats row per shard, indexed by shard id");
    assert_eq!(stats.routed.len(), 4, "one routed counter per shard, indexed by shard id");
    assert_eq!(
        stats.routed.iter().sum::<u64>(),
        8,
        "every submission accounted to exactly one shard"
    );
    let served: u64 = stats.shards.iter().map(|s| s.router.admitted).sum();
    assert!(served >= 1, "admitted counters must aggregate per shard");
}
