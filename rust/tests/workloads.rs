//! Structural regression tests for the nine workload builders: the
//! tile-DAG pipeline must stay faithful to each architecture's shape
//! (these are the query graphs every matching result depends on).

use immsched::graph::{is_acyclic, levels, topo_sort, NodeKind};
use immsched::workload::{
    assign_pipeline, build_model, tile_layer_graph, LayerOp, ModelId, TilingConfig, WorkloadClass,
};

#[test]
fn every_model_has_single_entry_path() {
    for id in ModelId::ALL {
        let g = build_model(id).to_dag();
        assert!(is_acyclic(&g), "{id:?}");
        assert!(!g.sources().is_empty(), "{id:?} has no source");
        assert!(!g.sinks().is_empty(), "{id:?} has no sink");
        // every node reachable from some source (no disconnected islands)
        let order = topo_sort(&g).unwrap();
        assert_eq!(order.len(), g.len());
    }
}

#[test]
fn llm_depth_matches_layer_count() {
    // Llama-3-8B: 32 blocks × ≥ 8 sequential ops + embed + head
    let g = build_model(ModelId::Llama3_8B).to_dag();
    let depth = levels(&g).into_iter().max().unwrap();
    assert!(depth >= 32 * 6, "transformer depth {depth} too shallow");
}

#[test]
fn cnn_pool_layers_are_compare_kind() {
    let g = build_model(ModelId::ResNet50);
    let pools: Vec<usize> = (0..g.len())
        .filter(|&i| matches!(g.layers[i].op, LayerOp::Pool { .. }))
        .collect();
    assert!(!pools.is_empty());
    let dag = g.to_dag();
    for p in pools {
        assert_eq!(dag.kind(p), NodeKind::Compare, "pool {p} kind");
    }
}

#[test]
fn tiling_is_deterministic() {
    for id in [ModelId::UNet, ModelId::Qwen7B] {
        let g = build_model(id);
        let a = tile_layer_graph(&g, TilingConfig::default());
        let b = tile_layer_graph(&g, TilingConfig::default());
        assert_eq!(a.len(), b.len(), "{id:?}");
        assert_eq!(a.dag.edge_count(), b.dag.edge_count(), "{id:?}");
        for (x, y) in a.tiles.iter().zip(&b.tiles) {
            assert_eq!(x.macs, y.macs, "{id:?}");
            assert_eq!(x.segment, y.segment, "{id:?}");
        }
    }
}

#[test]
fn tile_budget_respected_across_budgets() {
    let g = build_model(ModelId::PNasNet5);
    for max_tiles in [8usize, 12, 16, 24, 32, 48] {
        let t = tile_layer_graph(&g, TilingConfig { max_tiles, split_factor: 2 });
        assert!(t.len() <= max_tiles, "budget {max_tiles}: got {} tiles", t.len());
        assert!(is_acyclic(&t.dag));
    }
}

#[test]
fn pipeline_assignment_covers_all_tiles() {
    for id in ModelId::ALL {
        let g = build_model(id);
        let t = tile_layer_graph(&g, TilingConfig::default());
        let asg = assign_pipeline(&t.dag, 4);
        assert_eq!(asg.stage_of.len(), t.len(), "{id:?}");
        assert!(asg.num_stages >= 1 && asg.num_stages <= 4);
        // dependencies never go backwards through the pipeline
        for u in 0..t.len() {
            for &v in t.dag.successors(u) {
                assert!(asg.stage_of[u] <= asg.stage_of[v], "{id:?}: {u}->{v}");
            }
        }
    }
}

#[test]
fn class_medians_reflect_topological_complexity() {
    // Tile-level branchiness (edges per tile) must be highest for the
    // Middle (NAS) class — the paper's motivation for harder matching.
    let branchiness = |class: WorkloadClass| -> f64 {
        class
            .models()
            .iter()
            .map(|&m| {
                let t = tile_layer_graph(&build_model(m), TilingConfig::default());
                t.dag.edge_count() as f64 / t.len() as f64
            })
            .sum::<f64>()
            / 3.0
    };
    let simple = branchiness(WorkloadClass::Simple);
    let middle = branchiness(WorkloadClass::Middle);
    assert!(
        middle >= simple * 0.8,
        "middle {middle} unexpectedly far below simple {simple}"
    );
}

#[test]
fn weight_volumes_match_published_scales() {
    // int8 weight bytes ≈ parameter count
    let params_m = |id: ModelId| build_model(id).total_weight_bytes() as f64 / 1e6;
    assert!((2.0..6.0).contains(&params_m(ModelId::MobileNetV2)), "MobileNetV2 {} M", params_m(ModelId::MobileNetV2));
    assert!((20.0..30.0).contains(&params_m(ModelId::ResNet50)), "ResNet50 {} M", params_m(ModelId::ResNet50));
    assert!((25.0..40.0).contains(&params_m(ModelId::UNet)), "UNet {} M", params_m(ModelId::UNet));
    assert!((3.0..9.0).contains(&params_m(ModelId::EfficientNetB0)), "EfficientNet-B0 {} M", params_m(ModelId::EfficientNetB0));
}
