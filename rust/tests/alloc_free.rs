//! Steady-state allocation test for the interrupt hot path: after
//! warmup, `NativeEpochBackend::run_epoch_into` against a reused
//! `EpochOutputs` must not touch the heap at all — the backend's
//! persistent workspace (sparse fitness kernel, scratch arenas, RNG
//! streams) and the caller's flat buffers carry the whole epoch.
//!
//! This lives in its own test binary: the counting global allocator is
//! process-wide, and the default test harness runs tests concurrently —
//! any other test allocating during the measured window would make the
//! count meaningless.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use immsched::runtime::{
    EpochBackend, EpochInputs, EpochOutputs, NativeEpochBackend, NATIVE_SIZE_CLASSES,
};
use immsched::util::Rng;

/// System allocator wrapper counting every allocation-path entry
/// (alloc, alloc_zeroed, realloc — dealloc is free to happen).
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::SeqCst)
}

/// Sparse-ish random epoch inputs at a class's dims.
fn random_inputs(class: immsched::runtime::SizeClass, seed: u64) -> EpochInputs {
    let (p, n, m) = (class.particles, class.n, class.m);
    let mut rng = Rng::new(seed);
    let mut inputs = EpochInputs::zeros(class);
    inputs.mask.iter_mut().for_each(|x| *x = 1.0);
    for x in inputs.q.iter_mut() {
        *x = if rng.chance(0.2) { 1.0 } else { 0.0 };
    }
    for x in inputs.g.iter_mut() {
        *x = if rng.chance(0.3) { 1.0 } else { 0.0 };
    }
    for part in 0..p {
        for i in 0..n {
            let row = &mut inputs.s[(part * n + i) * m..(part * n + i + 1) * m];
            let mut sum = 0.0;
            for x in row.iter_mut() {
                *x = rng.f32() + 1e-3;
                sum += *x;
            }
            row.iter_mut().for_each(|x| *x /= sum);
        }
    }
    inputs.s_local.copy_from_slice(&inputs.s);
    inputs.s_star.copy_from_slice(&inputs.s[..n * m]);
    inputs.s_bar.copy_from_slice(&inputs.s[..n * m]);
    inputs.seed = 7;
    inputs
}

// NOTE: one single #[test] on purpose — the default harness runs tests
// in parallel, and a sibling test allocating during the measured window
// would corrupt the count.
#[test]
fn steady_state_run_epoch_allocates_nothing() {
    // medium class: 16 particles × 8 steps at 16×32 — well above the
    // trivial sizes, still fast in a debug test binary.
    let (name, class) = NATIVE_SIZE_CLASSES[1];
    // threads=1 pins the serial fan-out: spawning scoped threads
    // allocates in the OS path by design; the per-particle hot path is
    // identical either way (same slices, same scratch arenas).
    let mut backend = NativeEpochBackend::new(name, class).with_threads(1);
    let mut inputs = random_inputs(class, 1);
    let mut out = EpochOutputs::zeros(class);

    // warmup: first calls may size workspace-internal buffers
    for i in 0..3u32 {
        inputs.seed = i;
        backend.run_epoch_into(&inputs, &mut out).expect("warmup epoch");
    }

    let before = allocations();
    for i in 0..8u32 {
        inputs.seed = 100 + i; // fresh RNG streams, same dims
        backend.run_epoch_into(&inputs, &mut out).expect("steady epoch");
    }
    let after = allocations();
    assert_eq!(
        after - before,
        0,
        "steady-state run_epoch_into hit the allocator {} times",
        after - before
    );

    // sanity: the measured epochs really ran (outputs are live)
    assert!(out.f_local.iter().all(|f| f.is_finite()));

    // and the convenience wrapper (fresh outputs per call — it allocates
    // by contract, only run_epoch_into carries the guarantee) agrees
    // with the in-place path bit for bit
    let (name, class) = NATIVE_SIZE_CLASSES[0];
    let mut backend = NativeEpochBackend::new(name, class).with_threads(1);
    let inputs = random_inputs(class, 2);
    let fresh = backend.run_epoch(&inputs).expect("fresh");
    let mut reused = EpochOutputs::zeros(class);
    backend.run_epoch_into(&inputs, &mut reused).expect("reused");
    assert_eq!(fresh.s, reused.s);
    assert_eq!(fresh.v, reused.v);
    assert_eq!(fresh.s_local, reused.s_local);
    assert_eq!(fresh.f_local, reused.f_local);
    assert_eq!(fresh.f_last, reused.f_last);
}
