//! Multi-host serving acceptance: socket shards round-trip the full
//! wire protocol over loopback TCP and Unix-domain streams, a severed
//! connection redials within its bounded backoff budget and loses zero
//! epochs (bit-identical to an uninterrupted run), a worker killed
//! mid-episode fails over and is replaced through the registry's join
//! protocol, and the cluster is built from — and routes only to —
//! heartbeat-live registry workers.
//!
//! The process tests spawn the real `immsched shard-listen` binary
//! (cargo builds it for integration tests and exposes the path via
//! `CARGO_BIN_EXE_immsched`).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use immsched::cluster::net::registry::{decode_fleet_reply, encode_fleet_msg};
use immsched::cluster::net::{
    registry_respawner, shards_from_registry, spawn_shard_listener, FleetMsg, FleetReply,
    ListenConfig, NetAddr, ReconnectConfig, RegistryServer, ShardListener, SocketShard,
};
use immsched::cluster::transport::{ShardTransport, TransportConfig};
use immsched::cluster::wire::{read_frame, write_frame};
use immsched::cluster::{
    LeastQueueDepth, MatchCluster, RoundRobin, SupervisedFleet, SupervisorConfig,
};
use immsched::coordinator::{
    MatchPath, MatchProblem, MatchService, RequestId, ServiceConfig, SubmitOptions,
};
use immsched::graph::{gen_chain, NodeKind};
use immsched::matcher::PsoConfig;
use immsched::scheduler::Priority;
use immsched::util::MatF;

/// The worker binary the listener-process tests spawn.
const WORKER_BIN: &str = env!("CARGO_BIN_EXE_immsched");

fn chain_problem(n: usize, m: usize) -> MatchProblem {
    let qd = gen_chain(n, NodeKind::Compute);
    let gd = gen_chain(m, NodeKind::Universal);
    MatchProblem::from_dags(&qd, &gd)
}

/// Full mask, no embedding (3-fan-out star into a chain): the episode
/// runs its whole epoch budget unless preempted/sliced.
fn infeasible_star_problem() -> MatchProblem {
    let mut q = MatF::zeros(4, 4);
    q[(0, 1)] = 1.0;
    q[(0, 2)] = 1.0;
    q[(0, 3)] = 1.0;
    let gd = gen_chain(8, NodeKind::Universal);
    MatchProblem::from_dense(&MatF::full(4, 8, 1.0), &q, &gd.adjacency())
}

/// A supervisor tuned for test cadences: fast heartbeat, short replay
/// backoff, a few extra replay attempts to ride out stale status
/// caches right after a kill.
fn fast_supervisor() -> SupervisorConfig {
    SupervisorConfig {
        heartbeat_interval: Duration::from_millis(10),
        backoff_base: Duration::from_millis(2),
        backoff_cap: Duration::from_millis(20),
        max_replays: 6,
        ..Default::default()
    }
}

/// Resubmit through the fleet, riding out the window where routing may
/// still steer onto a shard that just died (its cached status has not
/// expired yet — the cluster routes on a TTL'd view of shard health).
fn resubmit_insistently(fleet: &SupervisedFleet, id: RequestId, problem: &MatchProblem) {
    let mut attempts = 0;
    while let Err(e) = fleet.resubmit(id, problem.clone(), Priority::Normal, None) {
        attempts += 1;
        assert!(attempts < 200, "resubmit never found a live shard: {e:#}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Socket shards behind an in-process accept loop serve a routed batch
/// over loopback TCP exactly like local transports, and the listener
/// winds down cleanly once its connection budget is spent and drained.
#[test]
fn socket_shards_serve_a_routed_batch_over_loopback_tcp() {
    let listener = ShardListener::bind(&NetAddr::parse("127.0.0.1:0").unwrap()).unwrap();
    let addr = listener.local_addr().clone();
    let server = std::thread::spawn(move || {
        listener.serve(TransportConfig::default(), ListenConfig { max_conns: 2 })
    });

    let pso = PsoConfig { seed: 17, epochs: 20_000, repair_budget: 1_000, ..Default::default() };
    let transports: Vec<Arc<dyn ShardTransport>> = (0..2)
        .map(|_| {
            Arc::new(SocketShard::connect(addr.clone(), ServiceConfig::default(), pso).unwrap())
                as Arc<dyn ShardTransport>
        })
        .collect();
    let cluster = MatchCluster::with_transports(transports, Box::<RoundRobin>::default(), 64);
    assert_eq!(cluster.transport_kinds(), vec!["socket"; 2]);

    let tickets: Vec<_> = (0..6)
        .map(|_| cluster.submit(chain_problem(4, 8), Priority::Normal, Some(60.0)).unwrap())
        .collect();
    for t in tickets {
        assert!(t.wait().expect("every ticket answers").matched());
    }
    assert_eq!(cluster.stats().routed.iter().sum::<u64>(), 6);

    cluster.drain().expect("remote sessions drain cleanly");
    server.join().unwrap().expect("the listener winds down after its last drain");
}

/// The same protocol runs over a Unix-domain stream, and the listener
/// removes its socket file on the way out.
#[test]
fn socket_shards_serve_over_a_unix_domain_socket() {
    let path = std::env::temp_dir().join(format!("immsched-net-uds-{}.sock", std::process::id()));
    let listener = ShardListener::bind(&NetAddr::Uds(path.clone())).unwrap();
    let addr = listener.local_addr().clone();
    let server = std::thread::spawn(move || {
        listener.serve(TransportConfig::default(), ListenConfig { max_conns: 1 })
    });

    let pso = PsoConfig { seed: 17, epochs: 20_000, repair_budget: 1_000, ..Default::default() };
    let shard = SocketShard::connect(addr, ServiceConfig::default(), pso).unwrap();
    assert_eq!(shard.kind(), "socket");
    for id in 0..2u64 {
        shard.submit(id, chain_problem(4, 8), Priority::Normal, None, None).unwrap();
        assert!(shard.wait_response(id).unwrap().matched());
    }

    shard.drain().expect("the remote session drains cleanly");
    server.join().unwrap().expect("the listener winds down after the drain");
    assert!(!path.exists(), "the listener must remove its socket file on shutdown");
}

/// Acceptance: a connection severed mid-episode redials within the
/// bounded backoff budget, resubmits the interrupted request, and the
/// quota-sliced walk still completes *exactly* the uninterrupted epoch
/// budget with bit-identical results — a cut cable costs at most the
/// unpersisted tail of one slice, never epochs, never determinism.
#[test]
fn severed_connection_redials_within_budget_and_loses_zero_epochs() {
    let epochs = 40usize;
    let pso = PsoConfig { seed: 23, epochs, repair_budget: 1_000, ..Default::default() };
    let svc = ServiceConfig { epoch_quota: Some(15), ..Default::default() };
    let problem = infeasible_star_problem();

    // the uninterrupted reference walk, on a plain in-process service
    let reference = MatchService::spawn_configured(svc, pso).unwrap();
    let mut ref_resp = reference
        .submit_with(
            problem.clone(),
            Priority::Normal,
            None,
            SubmitOptions { id: Some(9), ..Default::default() },
        )
        .unwrap()
        .wait()
        .unwrap();
    let mut ref_total = ref_resp.epochs_run;
    while ref_resp.path == MatchPath::Cancelled {
        let snap = ref_resp.snapshot.clone().expect("sliced episode yields a snapshot");
        ref_resp = reference
            .submit_with(
                problem.clone(),
                Priority::Normal,
                None,
                SubmitOptions { id: Some(9), resume: Some(snap) },
            )
            .unwrap()
            .wait()
            .unwrap();
        ref_total += ref_resp.epochs_run;
    }
    assert_eq!(ref_total, epochs);

    // the same walk over a socket whose link is cut mid-first-slice
    // (the slice takes milliseconds, the sever lands in microseconds);
    // the accept budget leaves room for the redialed connections
    let listener = ShardListener::bind(&NetAddr::parse("127.0.0.1:0").unwrap()).unwrap();
    let addr = listener.local_addr().clone();
    let _server = std::thread::spawn(move || {
        listener.serve(TransportConfig::default(), ListenConfig { max_conns: 8 })
    });
    let rcfg = ReconnectConfig {
        max_redials: 5,
        backoff_base: Duration::from_millis(2),
        backoff_cap: Duration::from_millis(20),
    };
    let shard =
        SocketShard::connect_with(addr, svc, pso, TransportConfig::default(), rcfg).unwrap();

    let id: RequestId = 9;
    shard.submit(id, problem.clone(), Priority::Normal, None, None).unwrap();
    shard.sever();
    let mut resp = shard.wait_response(id).unwrap();
    let mut total = resp.epochs_run;
    let mut hops = 0;
    while resp.path == MatchPath::Cancelled {
        hops += 1;
        assert!(hops <= 16, "sliced episode did not converge after the sever");
        let snap = resp.snapshot.clone().expect("sliced episode yields a snapshot");
        shard.submit(id, problem.clone(), Priority::Normal, None, Some(snap)).unwrap();
        resp = shard.wait_response(id).unwrap();
        total += resp.epochs_run;
    }
    shard.drain().expect("the healed link still drains cleanly");

    assert!(resp.resumed, "the final slice must warm-start");
    assert_eq!(total, epochs, "epochs across the sever must add up to exactly one cold solve");
    let stats = shard.reconnect_stats();
    assert!(stats.redials >= 1, "the cut link must have been redialed: {stats:?}");
    assert!(stats.resubmits >= 1, "the interrupted request must be resubmitted: {stats:?}");

    // bit-identity with the uninterrupted reference walk
    assert_eq!(resp.path, ref_resp.path);
    assert_eq!(total, ref_total);
    assert_eq!(resp.mappings, ref_resp.mappings);
    assert_eq!(
        resp.best_fitness.to_bits(),
        ref_resp.best_fitness.to_bits(),
        "fitness must match the uninterrupted run to the bit"
    );
}

/// Acceptance (tentpole): a `shard-listen` worker killed mid-episode
/// over a real TCP socket fails over onto the surviving worker, the
/// supervisor refills the dead slot from the *registry* (a freshly
/// joined worker, not a local respawn), and the epochs across every
/// received slice add up to exactly the uninterrupted budget.
#[test]
fn killed_socket_worker_fails_over_and_rejoins_via_the_registry() {
    let epochs = 40usize;
    let pso = PsoConfig { seed: 23, epochs, repair_budget: 1_000, ..Default::default() };
    let svc = ServiceConfig { epoch_quota: Some(15), ..Default::default() };

    let server = RegistryServer::bind(
        &NetAddr::parse("127.0.0.1:0").unwrap(),
        Duration::from_millis(250),
    )
    .unwrap();
    let registry = server.registry();
    let reg = server.addr().to_string();

    let names = ["net-kill-w0", "net-kill-w1"];
    let mut children: Vec<_> = names
        .iter()
        .map(|name| {
            spawn_shard_listener(
                Path::new(WORKER_BIN),
                "127.0.0.1:0",
                &["--registry", &reg, "--name", name, "--heartbeat-ms", "20"],
                Duration::from_secs(10),
            )
            .unwrap()
        })
        .collect();
    let live = registry.wait_for_live(2, Duration::from_secs(10));
    assert_eq!(live.len(), 2, "both workers must join and heartbeat");

    let tcfg = TransportConfig::default();
    let rcfg = ReconnectConfig {
        max_redials: 2,
        backoff_base: Duration::from_millis(5),
        backoff_cap: Duration::from_millis(20),
    };
    let (transports, workers) = shards_from_registry(&registry, svc, pso, tcfg, rcfg).unwrap();
    let mut cluster = MatchCluster::with_transports(transports, Box::new(LeastQueueDepth), 64);
    // keep routing's view of a dead shard fresh — a long-lived stale
    // "healthy" cache entry would bounce replays off the corpse
    cluster.set_status_ttl(Duration::from_millis(5));
    let fleet = SupervisedFleet::new(Arc::new(cluster), fast_supervisor());
    let assigned: Arc<Mutex<BTreeMap<usize, u64>>> =
        Arc::new(Mutex::new(workers.iter().copied().enumerate().collect()));
    fleet.set_respawn(registry_respawner(
        Arc::clone(&registry),
        Arc::clone(&assigned),
        svc,
        pso,
        tcfg,
        rcfg,
        Duration::from_secs(10),
    ));

    let problem = infeasible_star_problem();
    let id = fleet.submit(problem.clone(), Priority::Normal, None).unwrap();
    // kill the worker the request was routed to, mid-episode: the first
    // quota slice takes milliseconds, the kill lands in microseconds
    let victim = fleet.shard_of(id).expect("submitted request must be ticketed");
    let victim_name = live
        .iter()
        .find(|w| w.worker == workers[victim])
        .expect("the routed slot maps to a registry worker")
        .name
        .clone();
    let victim_child =
        names.iter().position(|n| *n == victim_name).expect("the worker maps to a child");
    children[victim_child].kill();
    // a fresh worker joins; the respawner waits for exactly this (the
    // survivor is already assigned to the other slot, so it is skipped)
    let _replacement = spawn_shard_listener(
        Path::new(WORKER_BIN),
        "127.0.0.1:0",
        &["--registry", &reg, "--name", "net-kill-w2", "--heartbeat-ms", "20"],
        Duration::from_secs(10),
    )
    .unwrap();

    let mut resp = fleet.wait(id).unwrap();
    let mut total_epochs = resp.epochs_run;
    let mut hops = 0;
    while resp.path == MatchPath::Cancelled {
        hops += 1;
        assert!(hops <= 16, "episode did not converge after failover");
        resubmit_insistently(&fleet, id, &problem);
        resp = fleet.wait(id).unwrap();
        total_epochs += resp.epochs_run;
    }
    assert_ne!(resp.path, MatchPath::Shed, "two workers must absorb one worker death");
    assert!(resp.resumed, "the final slice must warm-start from a persisted barrier");
    assert_eq!(
        total_epochs, epochs,
        "epochs across the kill must add up to exactly one uninterrupted budget"
    );
    let failover = fleet.failover();
    assert!(failover.shards_failed >= 1, "the kill must be detected: {failover:?}");
    assert!(failover.replays >= 1, "the in-flight victim must be replayed: {failover:?}");
    assert!(
        failover.respawns >= 1,
        "the dead slot must be refilled from a registry join: {failover:?}"
    );
}

/// Acceptance: the cluster is built from — and routes only to —
/// joined, heartbeat-live workers.  A worker that joins but never
/// heartbeats falls out of the live set after the liveness window,
/// gets no shard slot, and is eventually evicted outright.
#[test]
fn registry_routes_only_to_heartbeat_live_workers() {
    let window = Duration::from_millis(150);
    let server = RegistryServer::bind(&NetAddr::parse("127.0.0.1:0").unwrap(), window).unwrap();
    let registry = server.registry();
    let reg = server.addr().to_string();

    let _live_child = spawn_shard_listener(
        Path::new(WORKER_BIN),
        "127.0.0.1:0",
        &["--registry", &reg, "--name", "net-live-a", "--heartbeat-ms", "25"],
        Duration::from_secs(10),
    )
    .unwrap();
    assert_eq!(registry.wait_for_live(1, Duration::from_secs(10)).len(), 1);

    // a worker that joins by hand over raw fleet frames and then never
    // heartbeats (its advertised address is never dialed, so a dead
    // port is fine); joining *after* the real worker is live keeps the
    // two live windows overlapping for the next assertion
    let mut silent = server.addr().connect(Duration::from_secs(5)).unwrap();
    silent.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let join =
        FleetMsg::Join { name: "net-silent-b".into(), addr: "tcp://127.0.0.1:1".into() };
    write_frame(&mut silent, &encode_fleet_msg(&join)).unwrap();
    let reply = read_frame(&mut silent).unwrap().expect("registry answers the join");
    let FleetReply::Welcome { worker: silent_id } = decode_fleet_reply(&reply).unwrap() else {
        panic!("a well-formed join must be welcomed");
    };
    assert_eq!(registry.wait_for_live(2, Duration::from_secs(10)).len(), 2);

    // let the silent worker age out of the window; the announcer keeps
    // the real worker beating well inside it
    std::thread::sleep(window * 2);
    let live = registry.live();
    assert_eq!(live.len(), 1, "only the heartbeating worker stays live");
    assert_eq!(live[0].name, "net-live-a");

    let pso = PsoConfig { seed: 17, epochs: 20_000, repair_budget: 1_000, ..Default::default() };
    let (transports, workers) = shards_from_registry(
        &registry,
        ServiceConfig::default(),
        pso,
        TransportConfig::default(),
        ReconnectConfig::default(),
    )
    .unwrap();
    assert_eq!(workers, vec![live[0].worker], "the cluster is built from live workers only");
    let cluster = MatchCluster::with_transports(transports, Box::new(LeastQueueDepth), 64);
    for _ in 0..3 {
        let ticket = cluster.submit(chain_problem(4, 8), Priority::Normal, None).unwrap();
        assert_eq!(ticket.shard, 0, "every submission lands on the one live worker");
        assert!(ticket.wait().unwrap().matched());
    }
    cluster.drain().expect("the live worker's session drains cleanly");

    assert_eq!(registry.evict_stale(), 1, "the silent worker is garbage-collected");
    assert!(!registry.heartbeat(silent_id), "an evicted worker cannot heartbeat back");
}

/// Observability acceptance: one request over a real process hop
/// (SocketShard → spawned `immsched shard-listen` worker) stitches
/// into a single timeline — the router's local spans plus the worker's
/// own spans riding back on the reply with the `remote` flag set — and
/// the trace context survives the wire bit-exactly even for ids above
/// 2^53 (where an f64 round-trip would corrupt them).
#[test]
fn socket_request_stitches_one_timeline_with_remote_worker_spans() {
    // the obs plane is process-global; this is the only test in this
    // binary that touches it, and it restores the disabled default
    immsched::obs::disable_all();
    immsched::obs::tracer().clear();
    immsched::obs::enable_all();

    let child = spawn_shard_listener(
        Path::new(WORKER_BIN),
        "127.0.0.1:0",
        &[],
        Duration::from_secs(30),
    )
    .unwrap();
    let pso = PsoConfig { seed: 17, epochs: 20_000, repair_budget: 1_000, ..Default::default() };

    // routed path: the cluster stamps a local Route span, the worker's
    // spans come back remote, and both land on the same request id
    let shard: Arc<dyn ShardTransport> = Arc::new(
        SocketShard::connect(child.addr().clone(), ServiceConfig::default(), pso).unwrap(),
    );
    let cluster =
        MatchCluster::with_transports(vec![Arc::clone(&shard)], Box::<RoundRobin>::default(), 64);
    let ticket = cluster.submit(chain_problem(4, 8), Priority::Normal, Some(60.0)).unwrap();
    let routed_id = ticket.id;
    assert!(ticket.wait().unwrap().matched());
    let timeline = immsched::obs::tracer().timeline(routed_id);
    assert!(
        timeline.iter().any(|e| !e.remote && e.kind == immsched::obs::SpanKind::Route),
        "the router's local Route span must be in the stitched timeline: {timeline:?}"
    );
    assert!(
        timeline.iter().any(|e| e.remote && e.kind == immsched::obs::SpanKind::Submit),
        "the worker's spans must ride back on the reply as remote: {timeline:?}"
    );

    // bit-exactness: submit directly with an id no f64 can represent;
    // every span the worker ships back must carry it verbatim
    let id: RequestId = (1u64 << 60) | 0x000f_ffff_ffff_fff1;
    shard.submit(id, chain_problem(4, 8), Priority::Normal, Some(60.0), None).unwrap();
    assert!(shard.wait_response(id).unwrap().matched());
    let remote: Vec<_> = immsched::obs::tracer()
        .timeline(id)
        .into_iter()
        .filter(|e| e.remote)
        .collect();
    assert!(!remote.is_empty(), "the traced submit must bring worker spans home");
    assert!(
        remote.iter().all(|e| e.id == id),
        "the trace context must round-trip bit-exactly: {remote:?}"
    );

    cluster.drain().expect("the worker session drains cleanly");
    immsched::obs::disable_all();
    immsched::obs::tracer().clear();
}
