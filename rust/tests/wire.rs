//! Wire-layer contract tests: encode→decode must be the identity for
//! every payload the shard protocol moves — across problem sizes and
//! edge densities, including bit-exact snapshot state — and the framed
//! reader must reject malformed and truncated streams loudly.

use immsched::cluster::wire::{
    decode_msg, decode_problem, decode_reply, decode_response, encode_msg, encode_problem,
    encode_reply, encode_response, read_frame, write_frame, ShardMsg, ShardReply, ShardStatus,
    MAX_FRAME_BYTES, WIRE_SCHEMA,
};
use immsched::coordinator::{
    ControllerStats, MatchPath, MatchProblem, MatchResponse, RouterStats, ServiceConfig,
    ServiceStats,
};
use immsched::graph::{gen_chain, gen_random_dag, NodeKind};
use immsched::matcher::{PsoConfig, SwarmSnapshot};
use immsched::obs::TraceCtx;
use immsched::scheduler::Priority;
use immsched::util::json::Json;
use immsched::util::Rng;

fn random_problem(n: usize, m: usize, density: f64, rng: &mut Rng) -> MatchProblem {
    let qd = gen_random_dag(n, density, rng, NodeKind::Compute);
    let gd = gen_random_dag(m, density, rng, NodeKind::Universal);
    MatchProblem::from_dags(&qd, &gd)
}

fn random_snapshot(n: usize, m: usize, rng: &mut Rng) -> SwarmSnapshot {
    SwarmSnapshot {
        n,
        m,
        s_star: (0..n * m).map(|_| rng.f32()).collect(),
        s_bar: (0..n * m).map(|_| rng.f32()).collect(),
        best_fitness: -rng.f32() * 100.0,
        have_star: rng.below(2) == 1,
        epochs_done: rng.below(10_000),
        rng: rng.fork(7),
        mappings: (0..rng.below(3))
            .map(|_| {
                (0..n)
                    .map(|_| if rng.below(5) == 0 { None } else { Some(rng.below(m)) })
                    .collect()
            })
            .collect(),
    }
}

/// Problems of many shapes and densities survive the codec exactly.
#[test]
fn problem_round_trip_across_sizes_and_densities() {
    let mut rng = Rng::new(0xB0A7);
    for &(n, m) in &[(2usize, 3usize), (4, 8), (8, 16), (16, 32), (32, 64)] {
        for &density in &[0.0, 0.1, 0.35, 0.8] {
            let p = random_problem(n, m, density, &mut rng);
            let doc = encode_problem(&p);
            // through the renderer/parser too — that is what actually
            // crosses the pipe
            let doc = Json::parse(&doc.render()).expect("rendered problem parses");
            let back = decode_problem(&doc).expect("decode");
            assert_eq!(back.query, p.query, "query n={n} m={m} d={density}");
            assert_eq!(back.target, p.target, "target n={n} m={m} d={density}");
            assert_eq!(back.mask, p.mask, "mask n={n} m={m} d={density}");
        }
    }
}

/// Snapshot state is the warm-start payload: every f32 bit, the RNG
/// words and the feasible set must survive render→parse→decode.
#[test]
fn snapshot_round_trip_is_bit_identical() {
    let mut rng = Rng::new(0x5EED);
    for &(n, m) in &[(2usize, 2usize), (4, 8), (9, 17), (16, 32)] {
        let snap = random_snapshot(n, m, &mut rng);
        let doc = Json::parse(&snap.to_json().render()).expect("rendered snapshot parses");
        let back = SwarmSnapshot::from_json(&doc).expect("decode");
        assert_eq!(back, snap, "snapshot n={n} m={m}");
        // explicit bit-level check on the attractors (PartialEq on f32
        // would hide a NaN substitution)
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.s_star), bits(&snap.s_star));
        assert_eq!(bits(&back.s_bar), bits(&snap.s_bar));
        assert_eq!(back.best_fitness.to_bits(), snap.best_fitness.to_bits());
        assert_eq!(back.rng.state(), snap.rng.state());
    }
}

/// Non-finite fitness values are real states (a shed response carries
/// `-inf`; a poisoned epoch could produce NaN) — the bit encoding must
/// carry them where a JSON float would collapse to null.
#[test]
fn snapshot_non_finite_fitness_survives() {
    let mut rng = Rng::new(3);
    for bad in [f32::NEG_INFINITY, f32::INFINITY, f32::NAN] {
        let mut snap = random_snapshot(3, 5, &mut rng);
        snap.best_fitness = bad;
        snap.s_star[2] = bad;
        let doc = Json::parse(&snap.to_json().render()).unwrap();
        let back = SwarmSnapshot::from_json(&doc).unwrap();
        assert_eq!(back.best_fitness.to_bits(), bad.to_bits());
        assert_eq!(back.s_star[2].to_bits(), bad.to_bits());
    }
}

/// Responses round-trip across every disposition path.
#[test]
fn response_round_trip_across_paths() {
    let mut rng = Rng::new(11);
    let paths = [
        MatchPath::NativeEpoch,
        MatchPath::NativeFallback,
        MatchPath::Ullmann,
        MatchPath::Vf2,
        MatchPath::Rejected,
        MatchPath::Cancelled,
        MatchPath::Shed,
    ];
    for (i, &path) in paths.iter().enumerate() {
        let resp = MatchResponse {
            id: (u64::MAX - 17).wrapping_add(i as u64), // ids past 2^53 must survive
            mappings: vec![vec![Some(1), None, Some(0)]],
            best_fitness: if path == MatchPath::Shed { f32::NEG_INFINITY } else { -0.5 },
            epochs_run: 42,
            host_seconds: 0.0625,
            path,
            resumed: i % 2 == 0,
            snapshot: if path == MatchPath::Cancelled {
                Some(random_snapshot(3, 4, &mut rng))
            } else {
                None
            },
        };
        let doc = Json::parse(&encode_response(&resp).render()).unwrap();
        let back = decode_response(&doc).unwrap();
        assert_eq!(back.id, resp.id);
        assert_eq!(back.mappings, resp.mappings);
        assert_eq!(back.best_fitness.to_bits(), resp.best_fitness.to_bits());
        assert_eq!(back.epochs_run, resp.epochs_run);
        assert_eq!(back.host_seconds, resp.host_seconds);
        assert_eq!(back.path, resp.path);
        assert_eq!(back.resumed, resp.resumed);
        assert_eq!(back.snapshot, resp.snapshot);
    }
}

/// Full message/reply envelopes round-trip through real frames.
#[test]
fn framed_messages_round_trip() {
    let mut rng = Rng::new(21);
    let problem = random_problem(4, 8, 0.3, &mut rng);
    let msgs = vec![
        ShardMsg::Hello {
            service: ServiceConfig { queue_depth: 9, epoch_quota: Some(4) },
            pso: PsoConfig { seed: 1 << 60, ..Default::default() },
        },
        ShardMsg::Submit {
            id: 77,
            problem: problem.clone(),
            priority: Priority::Urgent,
            timeout: Some(1.5),
            resume: Some(random_snapshot(4, 8, &mut rng)),
            trace: Some(TraceCtx { trace_id: (1 << 60) + 77, parent: u64::MAX - 2 }),
        },
        ShardMsg::Cancel { id: 77 },
        ShardMsg::Stats,
        ShardMsg::Drain,
    ];
    let mut buf = Vec::new();
    for msg in &msgs {
        write_frame(&mut buf, &encode_msg(msg)).unwrap();
    }
    let mut r = &buf[..];
    for msg in &msgs {
        let frame = read_frame(&mut r).unwrap().expect("frame present");
        let back = decode_msg(&frame).unwrap();
        match (msg, &back) {
            (ShardMsg::Hello { service, pso }, ShardMsg::Hello { service: s2, pso: p2 }) => {
                assert_eq!(service.queue_depth, s2.queue_depth);
                assert_eq!(service.epoch_quota, s2.epoch_quota);
                assert_eq!(pso.seed, p2.seed);
            }
            (
                ShardMsg::Submit { id, priority, timeout, resume, problem, trace },
                ShardMsg::Submit {
                    id: i2,
                    priority: p2,
                    timeout: t2,
                    resume: r2,
                    problem: pr2,
                    trace: tr2,
                },
            ) => {
                assert_eq!(id, i2);
                assert_eq!(priority, p2);
                assert_eq!(timeout, t2);
                assert_eq!(resume, r2);
                assert_eq!(problem.mask, pr2.mask);
                assert_eq!(trace, tr2, "trace context must survive the frame bit-exactly");
            }
            (ShardMsg::Cancel { id }, ShardMsg::Cancel { id: i2 }) => assert_eq!(id, i2),
            (ShardMsg::Stats, ShardMsg::Stats) | (ShardMsg::Drain, ShardMsg::Drain) => {}
            (want, got) => panic!("decoded {got:?}, wanted {want:?}"),
        }
    }
    assert!(read_frame(&mut r).unwrap().is_none());

    // replies too
    let replies = vec![
        ShardReply::Ready { schema: WIRE_SCHEMA.into() },
        ShardReply::Stats(ShardStatus {
            queue_depth: 3,
            in_flight: Some(Priority::Background),
            in_flight_id: Some((1 << 60) + 5),
            stats: ServiceStats {
                controller: ControllerStats { requests: 5, cancelled: 2, ..Default::default() },
                router: RouterStats { admitted: 7, depth: 3, ..Default::default() },
            },
        }),
        ShardReply::Drained { answered: 12 },
        ShardReply::Error { context: "boom".into() },
    ];
    let mut buf = Vec::new();
    for reply in &replies {
        write_frame(&mut buf, &encode_reply(reply)).unwrap();
    }
    let mut r = &buf[..];
    match decode_reply(&read_frame(&mut r).unwrap().unwrap()).unwrap() {
        ShardReply::Ready { schema } => assert_eq!(schema, WIRE_SCHEMA),
        other => panic!("{other:?}"),
    }
    match decode_reply(&read_frame(&mut r).unwrap().unwrap()).unwrap() {
        ShardReply::Stats(status) => {
            assert_eq!(status.queue_depth, 3);
            assert_eq!(status.in_flight, Some(Priority::Background));
            assert_eq!(status.in_flight_id, Some((1 << 60) + 5), "ids past 2^53 must survive");
            assert_eq!(status.stats.controller.requests, 5);
            assert_eq!(status.stats.controller.cancelled, 2);
            assert_eq!(status.stats.router.admitted, 7);
        }
        other => panic!("{other:?}"),
    }
    match decode_reply(&read_frame(&mut r).unwrap().unwrap()).unwrap() {
        ShardReply::Drained { answered } => assert_eq!(answered, 12),
        other => panic!("{other:?}"),
    }
    match decode_reply(&read_frame(&mut r).unwrap().unwrap()).unwrap() {
        ShardReply::Error { context } => assert_eq!(context, "boom"),
        other => panic!("{other:?}"),
    }
}

/// Every truncation point of a real frame is a loud error, not a hang
/// or a silent partial decode.
#[test]
fn truncated_frames_fail_at_every_cut() {
    let problem = MatchProblem::from_dags(
        &gen_chain(4, NodeKind::Compute),
        &gen_chain(8, NodeKind::Universal),
    );
    let mut buf = Vec::new();
    write_frame(
        &mut buf,
        &encode_msg(&ShardMsg::Submit {
            id: 5,
            problem,
            priority: Priority::Normal,
            timeout: None,
            resume: None,
            trace: None,
        }),
    )
    .unwrap();
    // cuts through the length prefix and through the payload
    for cut in [1usize, 2, 3, 4 + 1, buf.len() / 2, buf.len() - 1] {
        let mut r = &buf[..cut];
        let err = read_frame(&mut r).expect_err("cut at {cut} must fail");
        assert!(format!("{err:#}").contains("truncated"), "cut {cut}: {err:#}");
    }
    // full frame still decodes after all that
    let mut r = &buf[..];
    assert!(decode_msg(&read_frame(&mut r).unwrap().unwrap()).is_ok());
}

/// Garbage payloads and hostile length prefixes are rejected.
#[test]
fn malformed_frames_are_rejected() {
    // valid length prefix, invalid JSON payload
    let mut buf = Vec::new();
    let payload = b"not json at all";
    buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    buf.extend_from_slice(payload);
    let mut r = &buf[..];
    assert!(read_frame(&mut r).is_err(), "garbage payload must not decode");

    // valid JSON, wrong envelope
    let mut buf = Vec::new();
    write_frame(&mut buf, &Json::obj(vec![("schema", Json::from("bogus/v9"))])).unwrap();
    let mut r = &buf[..];
    let frame = read_frame(&mut r).unwrap().unwrap();
    assert!(decode_msg(&frame).is_err(), "wrong schema must not decode");

    // length prefix beyond the cap is refused before allocation
    let mut buf = Vec::new();
    buf.extend_from_slice(&((MAX_FRAME_BYTES + 1) as u32).to_be_bytes());
    buf.extend_from_slice(b"xxxx");
    let mut r = &buf[..];
    let err = read_frame(&mut r).unwrap_err();
    assert!(format!("{err:#}").contains("cap"), "{err:#}");

    // structurally valid frame, semantically broken snapshot
    let bogus = Json::obj(vec![("n", Json::from(3usize)), ("m", Json::from(3usize))]);
    assert!(SwarmSnapshot::from_json(&bogus).is_err(), "missing fields must fail decode");
}

/// A tiny document must not be able to demand an enormous allocation:
/// dimensions are capped before anything is sized from them.
#[test]
fn hostile_dimensions_are_rejected_before_allocation() {
    use immsched::cluster::wire::{decode_csr, decode_mask};
    // a ~60-byte mask document claiming 10^15 columns
    let huge_mask = Json::obj(vec![
        ("rows", Json::from(1usize)),
        ("cols", Json::Num(1e15)),
        ("set", Json::Arr(vec![Json::Arr(vec![])])),
    ]);
    assert!(decode_mask(&huge_mask).is_err(), "per-dimension cap must reject");
    // per-dim legal but the product would still be a 2^38-cell bitset
    let wide_mask = Json::obj(vec![
        ("rows", Json::from(1usize << 19)),
        ("cols", Json::from(1usize << 19)),
        ("set", Json::Arr(vec![])),
    ]);
    assert!(decode_mask(&wide_mask).is_err(), "cell-count cap must reject");
    let huge_csr =
        Json::obj(vec![("nodes", Json::Num(1e15)), ("edges", Json::Arr(vec![]))]);
    assert!(decode_csr(&huge_csr).is_err(), "csr node cap must reject");
    // snapshot dims are capped too, and empty arrays cannot sneak past
    // the shape check via an overflowing n*m
    let mut rng = Rng::new(4);
    let mut doc = random_snapshot(2, 2, &mut rng).to_json();
    if let Json::Obj(fields) = &mut doc {
        for (k, v) in fields.iter_mut() {
            if k == "n" || k == "m" {
                *v = Json::Num(1e15);
            }
        }
    }
    assert!(SwarmSnapshot::from_json(&doc).is_err(), "snapshot dim cap must reject");
}

/// A decoded feasible set must actually fit the problem shape — a
/// mapping with too few slots or an out-of-range target vertex is
/// corruption, not a match result.
#[test]
fn snapshot_with_out_of_shape_mappings_is_rejected() {
    let mut rng = Rng::new(6);
    let mut snap = random_snapshot(4, 8, &mut rng);
    snap.mappings = vec![vec![Some(1), None, Some(0), Some(2)]];
    let good = SwarmSnapshot::from_json(&snap.to_json()).expect("in-shape mapping decodes");
    assert_eq!(good.mappings, snap.mappings);
    // target vertex beyond m
    snap.mappings = vec![vec![Some(1), None, Some(0), Some(999)]];
    assert!(SwarmSnapshot::from_json(&snap.to_json()).is_err(), "vertex >= m must fail");
    // wrong slot count
    snap.mappings = vec![vec![Some(1)]];
    assert!(SwarmSnapshot::from_json(&snap.to_json()).is_err(), "len != n must fail");
}

/// An all-zero RNG state can only come from corruption (xoshiro never
/// reaches its zero fixed point) — it must fail decode, not silently
/// resume on a substituted stream.
#[test]
fn snapshot_with_zeroed_rng_state_is_rejected() {
    let mut rng = Rng::new(8);
    let mut doc = random_snapshot(3, 4, &mut rng).to_json();
    if let Json::Obj(fields) = &mut doc {
        for (k, v) in fields.iter_mut() {
            if k == "rng" {
                *v = Json::Arr(vec![Json::from("0000000000000000"); 4]);
            }
        }
    }
    let err = SwarmSnapshot::from_json(&doc).unwrap_err();
    assert!(format!("{err:#}").contains("all-zero"), "{err:#}");
}

/// A reader that hands out at most `chunk` bytes per `read` call — the
/// socket transports see exactly this shape whenever TCP segmentation
/// or a slow peer splits a frame across reads.
struct Dribble<'a> {
    data: &'a [u8],
    pos: usize,
    chunk: usize,
}

impl std::io::Read for Dribble<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = buf.len().min(self.chunk).min(self.data.len() - self.pos);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// Short reads are not errors: a frame split into 1-, 2- and 3-byte
/// dribbles — including splits *inside* the 4-byte length prefix —
/// must decode identically to a single contiguous read.
#[test]
fn split_frames_survive_byte_dribble_reads() {
    let mut rng = Rng::new(31);
    let problem = random_problem(4, 8, 0.3, &mut rng);
    let msgs = vec![
        ShardMsg::Submit {
            id: 9,
            problem,
            priority: Priority::Normal,
            timeout: Some(2.5),
            resume: Some(random_snapshot(4, 8, &mut rng)),
            trace: None,
        },
        ShardMsg::Stats,
        ShardMsg::Drain,
    ];
    let mut buf = Vec::new();
    for msg in &msgs {
        write_frame(&mut buf, &encode_msg(msg)).unwrap();
    }
    for chunk in [1usize, 2, 3, 7] {
        let mut r = Dribble { data: &buf, pos: 0, chunk };
        for msg in &msgs {
            let frame = read_frame(&mut r).unwrap().expect("frame present");
            let back = decode_msg(&frame).unwrap();
            match (msg, &back) {
                (
                    ShardMsg::Submit { id, resume, .. },
                    ShardMsg::Submit { id: i2, resume: r2, .. },
                ) => {
                    assert_eq!(id, i2, "chunk {chunk}");
                    assert_eq!(resume, r2, "chunk {chunk}: snapshot must survive the dribble");
                }
                (ShardMsg::Stats, ShardMsg::Stats) | (ShardMsg::Drain, ShardMsg::Drain) => {}
                (want, got) => panic!("chunk {chunk}: decoded {got:?}, wanted {want:?}"),
            }
        }
        assert!(read_frame(&mut r).unwrap().is_none(), "chunk {chunk}: clean EOF after the batch");
    }
}

/// A stream that dies *between* frames is a clean EOF, but one that
/// dies *inside* a frame is a loud truncation — and the frames before
/// the cut still decode.
#[test]
fn truncation_mid_stream_fails_after_decoding_prior_frames() {
    let mut buf = Vec::new();
    write_frame(&mut buf, &encode_msg(&ShardMsg::Cancel { id: 3 })).unwrap();
    let first_len = buf.len();
    write_frame(&mut buf, &encode_msg(&ShardMsg::Stats)).unwrap();
    for cut in [first_len + 2, buf.len() - 1] {
        let mut r = &buf[..cut];
        let frame = read_frame(&mut r).unwrap().expect("first frame intact");
        assert!(matches!(decode_msg(&frame).unwrap(), ShardMsg::Cancel { id: 3 }));
        let err = read_frame(&mut r).expect_err("cut inside the second frame must fail");
        assert!(format!("{err:#}").contains("truncated"), "cut {cut}: {err:#}");
    }
    // the same stream cut exactly on the frame boundary is a clean EOF
    let mut r = &buf[..first_len];
    assert!(read_frame(&mut r).unwrap().is_some());
    assert!(read_frame(&mut r).unwrap().is_none(), "a boundary cut is EOF, not truncation");
}

/// v3: every `Response` piggybacks the worker's status so the router's
/// TTL cache refreshes without a stats round-trip — present status
/// round-trips field-for-field, absent status stays absent.
#[test]
fn response_reply_piggybacks_status() {
    let mut rng = Rng::new(41);
    let resp = MatchResponse {
        id: 1 << 60,
        mappings: vec![vec![Some(0), Some(2), None]],
        best_fitness: -1.25,
        epochs_run: 17,
        host_seconds: 0.5,
        path: MatchPath::Cancelled,
        resumed: true,
        snapshot: Some(random_snapshot(3, 4, &mut rng)),
    };
    let status = ShardStatus {
        queue_depth: 4,
        in_flight: Some(Priority::Urgent),
        in_flight_id: Some((1 << 60) + 1),
        stats: ServiceStats {
            controller: ControllerStats { requests: 9, resumed: 3, ..Default::default() },
            router: RouterStats { admitted: 11, depth: 4, ..Default::default() },
        },
    };
    for carried in [Some(status), None] {
        let reply = ShardReply::Response {
            response: resp.clone(),
            status: carried.clone(),
            spans: vec![],
        };
        let doc = Json::parse(&encode_reply(&reply).render()).unwrap();
        match decode_reply(&doc).unwrap() {
            ShardReply::Response { response, status, spans } => {
                assert!(spans.is_empty());
                assert_eq!(response.id, resp.id);
                assert_eq!(response.snapshot, resp.snapshot);
                match (&carried, &status) {
                    (Some(want), Some(got)) => {
                        assert_eq!(got.queue_depth, want.queue_depth);
                        assert_eq!(got.in_flight, want.in_flight);
                        assert_eq!(got.in_flight_id, want.in_flight_id);
                        assert_eq!(got.stats.controller.requests, 9);
                        assert_eq!(got.stats.router.admitted, 11);
                    }
                    (None, None) => {}
                    (want, got) => panic!(
                        "status presence diverged: {:?} vs {:?}",
                        want.is_some(),
                        got.is_some()
                    ),
                }
            }
            other => panic!("{other:?}"),
        }
    }
}

/// Mixing wire versions must fail loudly and helpfully: an old
/// `immsched.shard-wire/` peer gets the redeploy hint, arbitrary
/// garbage schemas get the plain mismatch.
#[test]
fn older_wire_schema_is_rejected_with_the_mixed_version_hint() {
    let mut doc = encode_msg(&ShardMsg::Stats);
    if let Json::Obj(fields) = &mut doc {
        for (k, v) in fields.iter_mut() {
            if k == "schema" {
                *v = Json::from("immsched.shard-wire/v2");
            }
        }
    }
    let err = decode_msg(&doc).unwrap_err();
    let text = format!("{err:#}");
    assert!(text.contains("schema mismatch"), "{text}");
    assert!(text.contains("immsched.shard-wire/v2"), "{text}");
    assert!(text.contains(WIRE_SCHEMA), "{text}");
    assert!(text.contains("redeploy both sides"), "an old peer earns the versioning hint: {text}");

    let mut doc = encode_msg(&ShardMsg::Stats);
    if let Json::Obj(fields) = &mut doc {
        for (k, v) in fields.iter_mut() {
            if k == "schema" {
                *v = Json::from("bogus/v9");
            }
        }
    }
    let text = format!("{:#}", decode_msg(&doc).unwrap_err());
    assert!(text.contains("schema mismatch"), "{text}");
    assert!(!text.contains("redeploy both sides"), "garbage is not a version skew: {text}");
}
