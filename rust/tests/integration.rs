//! Cross-module integration tests: artifact → runtime → coordinator,
//! trace → simulator → metrics, config → launcher plumbing, failure
//! injection.

use immsched::accel::{build_target_graph, Platform, PlatformKind};
use immsched::config::Config;
use immsched::coordinator::{CancelToken, GlobalController, MatchPath, MatchProblem, MatchService};
use immsched::matcher::{build_mask, mapping_is_feasible, PsoConfig, QuantizedMatcher};
use immsched::scheduler::{
    build_trace, metrics, FrameworkKind, Priority, SimConfig, Simulator, Task, TraceConfig,
};
use immsched::workload::{ModelId, TilingConfig, WorkloadClass};

/// The full pipeline on a real workload: model → tiles → target graph →
/// matcher → feasible engine mapping.
#[test]
fn model_to_engine_mapping_pipeline() {
    let platform = Platform::edge();
    let task = Task::new(0, ModelId::ResNet50, Priority::Urgent, 0.0, TilingConfig::default());
    let preemptible = vec![true; platform.engines];
    let (target, vertex_engine) = build_target_graph(&platform, &preemptible);
    let mask = build_mask(&task.tiles.dag, &target);
    let q = task.tiles.dag.adjacency();
    let g = target.adjacency();

    let out = QuantizedMatcher::new(PsoConfig { seed: 1, ..Default::default() }).run(&mask, &q, &g);
    assert!(out.matched(), "ResNet50 tiles must embed into an idle Edge platform");
    let mapping = &out.mappings[0];
    assert!(mapping_is_feasible(mapping, &q, &g));
    // mapping resolves to distinct physical engines
    let engines: Vec<usize> = mapping.iter().flatten().map(|&v| vertex_engine[v]).collect();
    let mut dedup = engines.clone();
    dedup.sort_unstable();
    dedup.dedup();
    assert_eq!(dedup.len(), engines.len(), "engine collision in mapping");
}

/// The epoch-backend path (native by default, PJRT when compiled in)
/// and the quantized fallback agree on feasibility for the same problem
/// — both behind the same typed request API.
#[test]
fn epoch_and_fallback_paths_agree() {
    let qd = immsched::graph::gen_chain(5, immsched::graph::NodeKind::Compute);
    let gd = immsched::graph::gen_chain(10, immsched::graph::NodeKind::Universal);
    let problem = MatchProblem::from_dags(&qd, &gd);
    let (q, g) = (qd.adjacency(), gd.adjacency());
    let cancel = CancelToken::new();

    let mut fallback = GlobalController::fallback_only(PsoConfig { seed: 3, ..Default::default() });
    let fallback_out = fallback.serve(&problem.request(1, Priority::Urgent, None), &cancel);
    assert!(fallback_out.matched());
    assert_eq!(fallback_out.path, MatchPath::NativeFallback);

    let mut full = GlobalController::new(PsoConfig { seed: 3, ..Default::default() })
        .expect("controller construction never fails in a default build");
    let epoch_out = full.serve(&problem.request(2, Priority::Urgent, None), &cancel);
    assert!(epoch_out.matched(), "epoch path failed where the fallback succeeded");
    for mp in &epoch_out.mappings {
        assert!(mapping_is_feasible(mp, &q, &g));
    }
}

/// Failure injection: pointing the registry at a corrupt artifact tree
/// must degrade to the native matcher, not crash.
#[test]
fn corrupt_artifacts_degrade_gracefully() {
    let dir = std::env::temp_dir().join("immsched_corrupt_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), "broken 8 16 8 8\n").unwrap();
    std::fs::write(dir.join("pso_epoch_broken.hlo.txt"), "THIS IS NOT HLO").unwrap();
    std::env::set_var("IMMSCHED_ARTIFACTS", &dir);

    let service = MatchService::spawn(PsoConfig { seed: 5, ..Default::default() }).unwrap();
    let qd = immsched::graph::gen_chain(4, immsched::graph::NodeKind::Compute);
    let gd = immsched::graph::gen_chain(8, immsched::graph::NodeKind::Universal);
    let problem = MatchProblem::from_dags(&qd, &gd);
    let resp = service.match_blocking(problem, Priority::Urgent, None).unwrap();
    assert_ne!(resp.path, MatchPath::Pjrt, "corrupt artifact must not be used");
    assert!(resp.matched(), "native path must still match");

    std::env::remove_var("IMMSCHED_ARTIFACTS");
    std::fs::remove_dir_all(&dir).ok();
}

/// End-to-end simulation for every framework on a small trace: no
/// panics, conservation, sane records.
#[test]
fn all_frameworks_simulate_cleanly() {
    for framework in FrameworkKind::ALL {
        let cfg = SimConfig { framework, ..Default::default() };
        let platform = Platform::get(cfg.platform_kind);
        let trace_cfg = TraceConfig {
            class: WorkloadClass::Simple,
            arrival_rate: 60.0,
            horizon: 0.02,
            seed: 11,
            ..Default::default()
        };
        let tasks = build_trace(&trace_cfg, &platform);
        let n = tasks.len();
        let res = Simulator::new(cfg).run(tasks, trace_cfg.horizon);
        assert_eq!(res.records.len(), n, "{framework:?} lost records");
        let s = metrics::summarize(&res);
        assert!(s.completed > 0, "{framework:?} completed nothing");
        assert!(s.energy_j > 0.0, "{framework:?} burned no energy");
    }
}

/// The paper's headline ordering on one consistent trace: IMMSched's
/// urgent latency beats IsoSched beats the LTS baselines.
#[test]
fn headline_ordering_holds() {
    let run = |framework| {
        let cfg = SimConfig { framework, ..Default::default() };
        let platform = Platform::get(cfg.platform_kind);
        let trace_cfg = TraceConfig {
            class: WorkloadClass::Simple,
            arrival_rate: 80.0,
            horizon: 0.03,
            seed: 21,
            ..Default::default()
        };
        let tasks = build_trace(&trace_cfg, &platform);
        let res = Simulator::new(cfg).run(tasks, trace_cfg.horizon);
        metrics::summarize(&res)
    };
    let imm = run(FrameworkKind::ImmSched);
    let iso = run(FrameworkKind::IsoSched);
    let moca = run(FrameworkKind::Moca);
    assert!(imm.sched_latency < iso.sched_latency, "imm sched must beat isosched");
    assert!(iso.sched_latency < moca.sched_latency, "isosched sched must beat LTS");
    assert!(imm.urgent_latency <= iso.urgent_latency * 1.5, "imm total latency regressed");
    assert!(imm.urgent_latency < moca.urgent_latency, "imm must beat LTS total latency");
}

/// Config file → simulation plumbing.
#[test]
fn config_file_drives_simulation() {
    let dir = std::env::temp_dir().join("immsched_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.toml");
    std::fs::write(
        &path,
        r#"
platform = "cloud"
[scheduler]
name = "isosched"
[sim]
arrival_rate = 75.0
horizon = 0.01
[workload]
class = "middle"
"#,
    )
    .unwrap();
    let cfg = Config::from_file(&path).unwrap();
    assert_eq!(cfg.platform, PlatformKind::Cloud);
    assert_eq!(cfg.workload.class, WorkloadClass::Middle);
    let framework = FrameworkKind::from_name(&cfg.scheduler.name).unwrap();
    assert_eq!(framework, FrameworkKind::IsoSched);
    // end-to-end through the simulator
    let platform = Platform::get(cfg.platform);
    let trace_cfg = TraceConfig {
        class: cfg.workload.class,
        arrival_rate: cfg.sim.arrival_rate,
        horizon: cfg.sim.horizon,
        seed: cfg.sim.seed,
        ..Default::default()
    };
    let tasks = build_trace(&trace_cfg, &platform);
    let sim_cfg = SimConfig { platform_kind: cfg.platform, framework, ..Default::default() };
    let res = Simulator::new(sim_cfg).run(tasks, trace_cfg.horizon);
    assert!(res.completed_count() > 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// ILP tensor export: a feasible simulated schedule validates against
/// the §3.1 constraints.
#[test]
fn sim_schedule_exports_valid_ilp_tensors() {
    use immsched::accel::ilp::{MappingTensors, TensorDims};
    // Build a small synthetic placement mirroring what the TSS
    // dispatcher does: 3 tasks × 4 tiles on 16 engines, slots by level.
    let platform = Platform::edge();
    let mut tensors = MappingTensors::new(TensorDims {
        dnns: 3,
        iterations: 1,
        tiles: 4,
        slots: 16,
        engines: platform.engines,
    });
    let mut engine = 0;
    for dnn in 0..3 {
        for tile in 0..4 {
            tensors.place(dnn, 0, tile, tile, engine);
            engine += 1;
        }
    }
    tensors.validate(&[(0, 1), (1, 2), (2, 3)]).expect("valid schedule rejected");
}
