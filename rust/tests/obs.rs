//! Observability-plane acceptance: the conservation property (every
//! submitted request id ends with exactly one terminal span, even when
//! seeded chaos forces replays and warm-start resubmissions), same-seed
//! determinism under the logical clock, and the versioned
//! flight-recorder dump document.
//!
//! The plane is process-global state (registry, tracer, recorder,
//! clock), so every test here serializes on [`OBS_GUARD`] and restores
//! the disabled default before releasing it.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use immsched::cluster::driver::{run_open_loop, schedule_from_trace, DriverConfig, DriverReport};
use immsched::cluster::transport::{InProcessShard, ShardTransport};
use immsched::cluster::{
    ChaosFault, ChaosSchedule, ClusterConfig, FaultInjectingTransport, MatchCluster, RoundRobin,
    SupervisedFleet, SupervisorConfig,
};
use immsched::matcher::PsoConfig;
use immsched::obs;
use immsched::scheduler::ArrivalProcess;
use immsched::util::json::Json;
use immsched::workload::WorkloadClass;

/// Serializes tests that toggle the process-global observability state.
static OBS_GUARD: Mutex<()> = Mutex::new(());

fn obs_guard() -> MutexGuard<'static, ()> {
    match OBS_GUARD.lock() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Fresh plane: everything cleared, logical clock, all layers on.
fn reset_plane_logical() {
    obs::disable_all();
    obs::tracer().clear();
    obs::recorder().clear();
    obs::clock::use_logical();
    obs::enable_all();
}

/// Leave the plane as other tests (and the library default) expect it.
fn teardown_plane() {
    obs::disable_all();
    obs::tracer().clear();
    obs::recorder().clear();
    obs::clock::use_wall();
}

/// One open-loop driver run against a supervised fleet of in-process
/// shards behind seeded fault injectors: a dropped reply on each shard
/// (forcing heartbeat-failover replays) plus a delay, all scripted.
fn chaos_run(seed: u64) -> (DriverReport, BTreeMap<u64, usize>) {
    reset_plane_logical();

    let pso = PsoConfig { seed, epochs: 20, repair_budget: 1_000, ..Default::default() };
    let svc = immsched::coordinator::ServiceConfig::default();
    let schedules = [
        ChaosSchedule::default()
            .at(0, ChaosFault::Delay(Duration::from_millis(2)))
            .at(1, ChaosFault::DropReply),
        ChaosSchedule::default().at(2, ChaosFault::DropReply),
    ];
    let transports: Vec<Arc<dyn ShardTransport>> = schedules
        .iter()
        .enumerate()
        .map(|(shard, schedule)| {
            let inner: Arc<dyn ShardTransport> =
                Arc::new(InProcessShard::spawn(svc, pso).unwrap());
            Arc::new(FaultInjectingTransport::new(inner, schedule.clone(), seed ^ shard as u64))
                as Arc<dyn ShardTransport>
        })
        .collect();
    let ccfg = ClusterConfig { shards: 2, pso, ..Default::default() };
    let cluster = Arc::new(MatchCluster::with_transports(
        transports,
        Box::<RoundRobin>::default(),
        ccfg.resume_capacity,
    ));
    let fleet = SupervisedFleet::new(
        cluster,
        SupervisorConfig {
            heartbeat_interval: Duration::from_millis(10),
            backoff_base: Duration::from_millis(2),
            backoff_cap: Duration::from_millis(20),
            max_replays: 6,
            ..Default::default()
        },
    );
    let dcfg = DriverConfig {
        class: WorkloadClass::Simple,
        process: ArrivalProcess::bursty_default(),
        arrival_rate: 200.0,
        horizon: 0.03,
        seed,
        time_scale: 0.0,
        resubmit_cancelled: true,
        ..Default::default()
    };
    let schedule = schedule_from_trace(&dcfg);
    assert!(schedule.len() >= 3, "trace too small to trip the scripted faults");
    let report = run_open_loop(&fleet, &schedule, &dcfg).unwrap();
    let _ = fleet.drain();
    let counts = obs::tracer().terminal_counts();
    obs::disable_all();
    (report, counts)
}

/// Conservation: chaos may drop replies, force replays, and trigger
/// warm-start resubmissions, but every submitted request id ends its
/// life with exactly one terminal span — no request vanishes, none is
/// double-terminated.  And because request ids and the logical clock
/// are both deterministic, two same-seed runs conserve identically.
#[test]
fn every_submitted_id_gets_exactly_one_terminal_span_under_chaos() {
    let _guard = obs_guard();
    let (report, counts) = chaos_run(0xB0B);

    let mut submitted: Vec<u64> = report.outcomes.iter().map(|o| o.id).collect();
    submitted.sort_unstable();
    assert_eq!(submitted.len(), report.submitted());
    for id in &submitted {
        assert_eq!(
            counts.get(id),
            Some(&1),
            "request {id} must have exactly one terminal span: {counts:?}"
        );
    }
    assert_eq!(
        counts.len(),
        submitted.len(),
        "terminal spans for ids the driver never settled: {counts:?}"
    );
    assert_eq!(obs::tracer().dropped(), 0, "tracer capacity must hold the whole run");

    let (report2, counts2) = chaos_run(0xB0B);
    let mut submitted2: Vec<u64> = report2.outcomes.iter().map(|o| o.id).collect();
    submitted2.sort_unstable();
    assert_eq!(submitted, submitted2, "same seed must submit the same request ids");
    assert_eq!(counts, counts2, "same seed must conserve identically");

    teardown_plane();
}

/// The dump document: versioned schema, the incident ring, a metrics
/// snapshot, and the request timelines — parseable by `util::json` (the
/// same parser `immsched metrics --in` uses).
#[test]
fn flight_recorder_dump_round_trips_through_the_json_parser() {
    let _guard = obs_guard();
    reset_plane_logical();

    obs::trace::span(7, obs::SpanKind::Submit);
    obs::trace::terminal(7, obs::SpanKind::Done, || "path=native-epoch".into());
    obs::recorder::record(
        "shard-dead",
        vec![("shard".into(), "1".into()), ("healthy".into(), "0".into())],
    );

    let dir = std::env::temp_dir();
    let path = dir.join(format!("immsched-obs-dump-{}.json", std::process::id()));
    obs::recorder::set_dump_path(Some(path.clone()));
    obs::recorder::dump_to_disk("shard-dead");
    obs::recorder::set_dump_path(None);

    let text = std::fs::read_to_string(&path).expect("dump file written");
    std::fs::remove_file(&path).ok();
    let doc = Json::parse(&text).expect("dump parses");
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some(obs::OBS_DUMP_SCHEMA));
    assert_eq!(doc.get("reason").and_then(Json::as_str), Some("shard-dead"));
    let events = doc.get("events").and_then(Json::as_array).expect("events array");
    assert!(
        events.iter().any(|e| e.get("kind").and_then(Json::as_str) == Some("shard-dead")),
        "the recorded incident must appear in the ring"
    );
    assert!(doc.get("metrics").is_some(), "dump carries a metrics snapshot");
    let timelines = doc.get("timelines").expect("dump carries timelines");
    let spans = timelines
        .get(&format!("{:016x}", 7u64))
        .and_then(Json::as_array)
        .expect("request 7 timeline");
    assert_eq!(spans.len(), 2);
    assert_eq!(
        spans[1].get("terminal").and_then(Json::as_bool),
        Some(true),
        "the Done span is terminal"
    );

    teardown_plane();
}

/// Disabled-plane discipline: with everything off (the default), the
/// convenience probes record nothing — the hot path stays empty.
#[test]
fn disabled_plane_records_nothing() {
    let _guard = obs_guard();
    teardown_plane();

    obs::trace::span(99, obs::SpanKind::Submit);
    obs::trace::terminal(99, obs::SpanKind::Done, || unreachable!("detail must stay lazy"));
    obs::recorder::record("never", vec![]);
    assert!(obs::tracer().timeline(99).is_empty());
    assert_eq!(obs::recorder().events().iter().filter(|e| e.kind == "never").count(), 0);
}
