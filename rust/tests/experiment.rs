//! Integration gates for `cluster::experiment`: the campaign summary
//! is a pure function of (grid, campaign seed) regardless of worker
//! pool width, the LBT search spends a bounded and fully accounted
//! probe budget, the quota tournament shows the adaptive policy
//! winning or tying every static quota, and the rendered report covers
//! every policy and every grid cell.

use immsched::cluster::experiment::{
    bisect_max_rate, run_campaign, summary_json, ExperimentGrid, LbtConfig,
};
use immsched::report::figures::experiment_report;
use immsched::util::json::Json;

#[test]
fn campaign_summary_is_byte_identical_across_runs_and_pool_widths() {
    let grid = ExperimentGrid::smoke(7);
    let wide = run_campaign(&grid, 3).expect("campaign on 3 workers");
    let narrow = run_campaign(&grid, 1).expect("campaign on 1 worker");
    let a = summary_json(&grid, &wide).render();
    let b = summary_json(&grid, &narrow).render();
    assert_eq!(a, b, "summary must be a pure function of (grid, campaign seed)");

    // a different campaign seed must actually change the numbers
    let other = ExperimentGrid::smoke(8);
    let c = summary_json(&other, &run_campaign(&other, 2).expect("campaign")).render();
    assert_ne!(a, c, "campaign seed must reach the replication RNGs");
}

#[test]
fn lbt_bisection_terminates_within_its_accounted_probe_budget() {
    let cfg = LbtConfig { target_miss: 0.1, hi0: 50.0, max_doublings: 5, bisections: 12 };
    // synthetic monotone SLO-miss ramp crossing the target at rate 130
    let mut calls = 0usize;
    let out = bisect_max_rate(
        |rate| {
            calls += 1;
            assert!(calls <= cfg.probe_budget(), "probe #{calls} exceeds the budget");
            (rate / 1300.0).min(1.0)
        },
        &cfg,
    );
    assert_eq!(out.probes, calls, "every probe must be accounted");
    assert!(!out.saturated_budget);
    assert!((out.rate - 130.0).abs() < 2.0, "LBT {} should be ~130", out.rate);
}

#[test]
fn smoke_tournament_adaptive_quota_dominates_and_report_covers_the_grid() {
    let grid = ExperimentGrid::smoke(42);
    let result = run_campaign(&grid, 4).expect("smoke campaign");
    let summary = summary_json(&grid, &result);

    // every route policy got an LBT point with a concrete rate
    let lbt = summary.get("lbt").and_then(Json::as_array).expect("lbt array");
    assert_eq!(lbt.len(), grid.policies.len());
    for p in lbt {
        assert!(p.get("lbt_rate").and_then(Json::as_f64).is_some(), "{p:?} has no rate");
    }

    // every grid cell got a summary row
    let cells = summary.get("cells").and_then(Json::as_array).expect("cells array");
    assert_eq!(cells.len(), grid.cells().len());

    // the adaptive quota wins or ties every static quota on mean SLO miss
    let tournament = summary.get("tournament").and_then(Json::as_array).expect("tournament");
    let adaptive = tournament
        .iter()
        .find(|q| q.get("quota").and_then(Json::as_str) == Some("adaptive"))
        .expect("adaptive tournament row");
    let adaptive_miss = adaptive
        .get("slo_miss_rate")
        .and_then(Json::as_f64)
        .expect("adaptive row has a finite miss rate");
    for q in tournament {
        let name = q.get("quota").and_then(Json::as_str).unwrap_or("?");
        let miss = q.get("slo_miss_rate").and_then(Json::as_f64).unwrap_or(f64::NAN);
        assert!(
            adaptive_miss <= miss + 1e-9,
            "adaptive ({adaptive_miss:.4}) loses to {name} ({miss:.4})"
        );
    }
    assert_eq!(
        adaptive.get("best").and_then(Json::as_bool),
        Some(true),
        "the adaptive row must carry the best flag"
    );

    // the rendered report: LBT + tournament + per-cell tables, all populated
    let tables = experiment_report(&summary);
    assert_eq!(tables.len(), 3);
    for t in &tables {
        let text = t.render();
        assert!(text.lines().count() > 3, "table renders with rows:\n{text}");
    }
}
