use immsched::report::figures::*;
use immsched::accel::PlatformKind;
use immsched::scheduler::*;
use immsched::workload::WorkloadClass;
fn main() {
    let params = FigureParams::default();
    for (fw, class) in [(FrameworkKind::Prema, WorkloadClass::Simple), (FrameworkKind::ImmSched, WorkloadClass::Complex)] {
        let res = run_cell(PlatformKind::Edge, class, fw, 100.0, &params);
        println!("=== {:?} {:?}: {} records", fw, class, res.records.len());
        for r in res.urgent() {
            println!("  urgent id={} model={:?} arr={:.4} sched={:.6} start={:?} done={:?} dl={:?} met={}",
                r.id, r.model, r.arrival, r.sched_seconds, r.started.map(|x| (x*1e3).round()/1e3), r.completed.map(|x| (x*1e3).round()/1e3), r.deadline.map(|x| (x*1e3).round()/1e3), r.deadline_met());
        }
        let bg_done = res.records.iter().filter(|r| r.priority==Priority::Background && r.completed.is_some()).count();
        let bg = res.records.iter().filter(|r| r.priority==Priority::Background).count();
        println!("  background {}/{} completed", bg_done, bg);
    }
}
