//! END-TO-END driver (EXPERIMENTS.md §E2E): the open-ended scenario of
//! paper Fig. 1(c) on a real small workload.
//!
//! All three layers compose here:
//! * **L1/L2**: the coordinator serves every urgent interrupt through the
//!   AOT-lowered Pallas/JAX PSO epoch via PJRT (fallback logged if the
//!   artifacts are missing),
//! * **L3**: the event-driven platform simulator executes the same trace
//!   under IMMSched and under the strongest baseline (IsoSched) plus one
//!   LTS baseline (MoCA), reporting the paper's three metrics.
//!
//! Run: `cargo run --release --example interruptible_serving`
//! (needs `make artifacts` for the PJRT path).

use immsched::accel::{build_target_graph, Platform, PlatformKind};
use immsched::coordinator::{MatchPath, MatchProblem, MatchService};
use immsched::matcher::PsoConfig;
use immsched::report;
use immsched::scheduler::{
    build_trace, metrics, FrameworkKind, Priority, SimConfig, Simulator, TraceConfig,
};
use immsched::util::table::{fmt_ratio, fmt_time, Table};
use immsched::workload::WorkloadClass;

fn main() -> anyhow::Result<()> {
    let platform_kind = PlatformKind::Edge;
    let platform = Platform::get(platform_kind);
    let class = WorkloadClass::Simple;
    let horizon = 0.05;
    let arrival_rate = 150.0;

    println!("== interruptible serving: open-ended scenario ==");
    println!(
        "platform {} ({} engines), workload {}, λ = {arrival_rate}/s over {horizon}s\n",
        platform.kind.name(),
        platform.engines,
        class.name()
    );

    // --- Part 1: live coordinator serving the urgent interrupts ---------
    // Drive the *actual* PJRT path for every distinct urgent model in the
    // trace — proving the L1/L2 artifacts serve the L3 hot path.
    let trace_cfg = TraceConfig { class, arrival_rate, horizon, ..Default::default() };
    let tasks = build_trace(&trace_cfg, &platform);
    let urgent_count = tasks.iter().filter(|t| t.is_urgent()).count();
    println!("trace: {} tasks ({} urgent interrupts)", tasks.len(), urgent_count);

    let service = MatchService::spawn(PsoConfig::default())?;
    let preemptible = vec![true; platform.engines];
    let (target, _) = build_target_graph(&platform, &preemptible);
    let mut served = 0usize;
    let mut matched = 0usize;
    let mut pjrt_used = 0usize;
    let mut host_seconds = 0.0;
    let mut seen_models = std::collections::HashSet::new();
    for task in tasks.iter().filter(|t| t.is_urgent()) {
        if !seen_models.insert(task.model) {
            continue; // one live episode per distinct model
        }
        let problem = MatchProblem::from_dags(&task.tiles.dag, &target);
        let resp = service.match_blocking(problem, Priority::Urgent, None)?;
        served += 1;
        matched += usize::from(resp.matched());
        pjrt_used += usize::from(resp.path == MatchPath::Pjrt);
        host_seconds += resp.host_seconds;
        println!(
            "  interrupt[{}]: {} -> {} mapping(s) via {} in {}",
            served,
            task.model.name(),
            resp.mappings.len(),
            resp.path.name(),
            fmt_time(resp.host_seconds)
        );
    }
    println!(
        "match service: {served} episodes, {matched} matched, {pjrt_used} on the PJRT path, {} total\n",
        fmt_time(host_seconds)
    );

    // --- Part 2: full-trace simulation, IMMSched vs baselines -----------
    let mut t = Table::new("open-ended scenario: IMMSched vs baselines").header(&[
        "framework", "completed", "urgent latency", "sched latency", "deadline rate",
        "energy", "tasks/J", "speedup", "eff. gain",
    ]);
    let mut summaries = Vec::new();
    for framework in [FrameworkKind::ImmSched, FrameworkKind::IsoSched, FrameworkKind::Moca] {
        let tasks = build_trace(&trace_cfg, &platform);
        let mut sim = Simulator::new(SimConfig {
            platform_kind,
            framework,
            ..Default::default()
        });
        let res = sim.run(tasks, horizon);
        summaries.push((framework, metrics::summarize(&res)));
    }
    let imm = summaries[0].1;
    for (framework, s) in &summaries {
        t.row(vec![
            framework.name().into(),
            s.completed.to_string(),
            fmt_time(s.urgent_latency),
            fmt_time(s.sched_latency),
            format!("{:.0}%", s.deadline_rate * 100.0),
            format!("{:.2} mJ", s.energy_j * 1e3),
            format!("{:.1}", s.tasks_per_joule),
            fmt_ratio(s.urgent_latency / imm.urgent_latency),
            fmt_ratio(imm.tasks_per_joule / s.tasks_per_joule),
        ]);
    }
    report::emit(&t, "e2e_interruptible_serving")?;

    println!(
        "\nExpected shape (paper Figs. 6-8): IMMSched's scheduling latency is orders\n\
         of magnitude below the serial baselines, so its urgent total latency and\n\
         deadline rate dominate; the TSS paradigm keeps its energy per task low."
    );
    Ok(())
}
