//! Matcher shoot-out: serial Ullmann vs float PSO vs quantized (u8/i32)
//! PSO on planted subgraph-isomorphism instances of growing size.
//!
//! Shows the paper's core algorithmic claims in isolation:
//! * the PSO matchers find embeddings the serial matcher also finds,
//! * the quantized datapath tracks the float one,
//! * the modeled on-accelerator episode cost collapses vs the CPU-serial
//!   cost as instances grow (the Fig. 2a mechanism).
//!
//! Run: `cargo run --release --example matcher_demo`

use immsched::accel::Platform;
use immsched::matcher::{
    mapping_is_feasible, ullmann::plant_embedding, ullmann_find_first, MatcherCostModel,
    PsoConfig, PsoMatcher, QuantizedMatcher,
};
use immsched::util::table::{fmt_time, Table};
use immsched::util::{MatF, Rng};

fn main() {
    let mut rng = Rng::new(2026);
    let cost_model = MatcherCostModel::default();
    let platform = Platform::edge();

    let mut t = Table::new("matcher shoot-out on planted instances").header(&[
        "n", "m", "Ullmann found", "Ullmann nodes", "CPU-serial time",
        "PSO found", "q8 found", "accel episode", "speedup",
    ]);

    for &(n, m) in &[(6usize, 14usize), (10, 24), (14, 32), (20, 48), (28, 64)] {
        let (q, g, _) = plant_embedding(n, m, 0.35, 0.12, &mut rng);
        let mask = MatF::full(n, m, 1.0);

        // serial Ullmann (IsoSched baseline)
        let (serial, stats) = ullmann_find_first(&mask, &q, &g, 5_000_000);
        let cpu = cost_model.cpu_serial(&stats, n, m);

        // float PSO (reference) + quantized PSO (hardware model)
        let pso_cfg = PsoConfig { seed: n as u64 * 31 + m as u64, ..Default::default() };
        let float_out = PsoMatcher::new(pso_cfg).run(&mask, &q, &g);
        let q8_out = QuantizedMatcher::new(pso_cfg).run(&mask, &q, &g);
        let accel = cost_model.accel_pso(&q8_out, n, m, pso_cfg.particles, &platform);

        for found in float_out.mappings.iter().chain(&q8_out.mappings) {
            assert!(mapping_is_feasible(found, &q, &g), "infeasible mapping escaped");
        }

        t.row(vec![
            n.to_string(),
            m.to_string(),
            serial.is_some().to_string(),
            stats.nodes_visited.to_string(),
            fmt_time(cpu.seconds),
            float_out.matched().to_string(),
            q8_out.matched().to_string(),
            fmt_time(accel.seconds),
            format!("{:.0}x", cpu.seconds / accel.seconds.max(1e-12)),
        ]);
    }
    print!("{}", t.render());

    println!(
        "\nNote: 'accel episode' is the modeled on-accelerator cost of the quantized\n\
         PSO episode (int8 MACs + NoC + controller), 'CPU-serial time' the modeled\n\
         cost of the measured Ullmann backtracking — the Fig. 2a mechanism."
    );
}
