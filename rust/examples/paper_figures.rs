//! Regenerate every table and figure of the paper's evaluation in one
//! run (Tables 1-2, Figs. 2a, 2b, 6, 7, 8), writing CSVs to `reports/`.
//!
//! Run: `cargo run --release --example paper_figures`
//! (the per-figure `cargo bench` harnesses add timing around the same
//! code paths; see rust/benches/.)

use immsched::report::{self, figures};

fn main() -> anyhow::Result<()> {
    let params = figures::FigureParams::default();

    println!(">>> Table 1/2");
    report::emit(&figures::table1(), "table1_capabilities")?;
    report::emit(&figures::table2(), "table2_platforms")?;

    println!(">>> Fig 2a (CPU-serial scheduling overhead)");
    report::emit(&figures::fig2a(&params), "fig2a_profiling")?;

    println!(">>> Fig 2b (continuous-relaxation stability)");
    let (t2b, xs, series) = figures::fig2b(&params);
    report::emit(&t2b, "fig2b_stability")?;
    report::emit_series(
        "fig2b_traces",
        "step",
        &["relaxed", "discrete"],
        &xs,
        &series,
    )?;

    println!(">>> Figs 6+8 grid (36 simulations)");
    let grid = figures::run_grid(&params);
    report::emit(&figures::fig6(&grid), "fig6_speedup")?;
    report::emit(&figures::fig8(&grid), "fig8_energy")?;

    println!(">>> Fig 7 (LBT sweep — the slow one)");
    report::emit(&figures::fig7(&params), "fig7_lbt")?;

    println!(">>> Perf trajectory (accumulated BENCH_matcher/BENCH_cluster entries)");
    let (matcher_path, cluster_path) = figures::default_trajectory_paths();
    let matcher_text = std::fs::read_to_string(&matcher_path).ok();
    let cluster_text = std::fs::read_to_string(&cluster_path).ok();
    let (traj, xs, series) =
        figures::perf_trajectory(matcher_text.as_deref(), cluster_text.as_deref())?;
    report::emit(&traj, "perf_trajectory")?;
    if !xs.is_empty() {
        report::emit_series(
            "perf_trajectory_series",
            "entry",
            &["largest_class_fitness_speedup", "largest_class_epoch_us"],
            &xs,
            &series,
        )?;
    }

    if let Some(cluster) = cluster_text.as_deref() {
        println!(">>> Observability plane (obs_overhead + incident counters)");
        report::emit(&figures::obs_trajectory(cluster)?, "obs_trajectory")?;
    }

    println!("all figures regenerated under reports/");
    Ok(())
}
