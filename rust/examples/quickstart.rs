//! Quickstart: the 60-second tour of the public API.
//!
//! 1. build a DNN workload and tile it (Layer Concatenate-and-Split),
//! 2. extract the preemptible target graph of the Edge platform,
//! 3. serve one urgent-task interrupt through the `MatchService` (sparse
//!    typed request → admission → engine chain: PJRT epoch artifact if
//!    built, native epoch backend otherwise, quantized fallback),
//! 4. run a short open-ended simulation and print the summary.
//!
//! Run: `cargo run --release --example quickstart`

use immsched::accel::{build_target_graph, Platform};
use immsched::coordinator::{MatchProblem, MatchService};
use immsched::matcher::PsoConfig;
use immsched::scheduler::{build_trace, metrics, Priority, SimConfig, Simulator, TraceConfig};
use immsched::util::table::fmt_time;
use immsched::workload::{build_model, tile_layer_graph, ModelId, TilingConfig};

fn main() -> anyhow::Result<()> {
    // --- 1. workload -> tile DAG (the matcher's query graph) ------------
    let model = ModelId::MobileNetV2;
    let graph = build_model(model);
    let tiles = tile_layer_graph(&graph, TilingConfig::default());
    println!(
        "{}: {} layers, {:.2} GMACs -> {} tiles in {} segments",
        model.name(),
        graph.len(),
        graph.total_macs() as f64 / 1e9,
        tiles.len(),
        tiles.num_segments
    );

    // --- 2. platform -> preemptible target graph ------------------------
    let platform = Platform::edge();
    let preemptible = vec![true; platform.engines]; // everything idle
    let (target, vertex_engine) = build_target_graph(&platform, &preemptible);
    println!(
        "{}: {} engines, target graph {} vertices / {} edges",
        platform.kind.name(),
        platform.engines,
        target.len(),
        target.edge_count()
    );

    // --- 3. one interrupt through the match service ---------------------
    let problem = MatchProblem::from_dags(&tiles.dag, &target);
    let service = MatchService::spawn(PsoConfig::default())?;
    let t0 = std::time::Instant::now();
    let resp = service.match_blocking(problem, Priority::Urgent, None)?;
    println!(
        "interrupt served in {} via {}: {} feasible mapping(s), best fitness {:.3}",
        fmt_time(t0.elapsed().as_secs_f64()),
        resp.path.name(),
        resp.mappings.len(),
        resp.best_fitness
    );
    if let Some(mapping) = resp.mappings.first() {
        let pairs: Vec<String> = mapping
            .iter()
            .enumerate()
            .filter_map(|(tile, &v)| v.map(|v| format!("t{tile}→e{}", vertex_engine[v])))
            .collect();
        println!("mapping: {}", pairs.join(" "));
    }

    // --- 4. a short open-ended simulation --------------------------------
    let trace_cfg = TraceConfig { horizon: 0.02, arrival_rate: 100.0, ..Default::default() };
    let tasks = build_trace(&trace_cfg, &platform);
    let mut sim = Simulator::new(SimConfig::default());
    let res = sim.run(tasks, trace_cfg.horizon);
    let s = metrics::summarize(&res);
    println!(
        "simulated {} tasks: {} completed, urgent deadline rate {:.0}%, {:.2} mJ total",
        res.records.len(),
        s.completed,
        s.deadline_rate * 100.0,
        s.energy_j * 1e3
    );
    Ok(())
}
