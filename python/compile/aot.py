"""AOT: lower the L2 epoch to HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT ``lowered.compile()``/``.serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's bundled xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``).  The text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md and gen_hlo.py.

Outputs (per size class in model.SIZE_CLASSES):
    artifacts/pso_epoch_<name>.hlo.txt
plus a manifest the rust artifact registry parses:
    artifacts/manifest.txt   lines: "<name> <n> <m> <particles> <k_steps>"

Run via ``make artifacts`` (no-op when inputs unchanged).  Python never
runs after this point; the rust binary is self-contained.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import SIZE_CLASSES, epoch_fn


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_size_class(name: str, n: int, m: int, particles: int, k_steps: int) -> str:
    fn, args = epoch_fn(n, m, particles, k_steps)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--classes",
        nargs="*",
        default=list(SIZE_CLASSES),
        help="size classes to lower (default: all)",
    )
    ns = parser.parse_args()

    os.makedirs(ns.out_dir, exist_ok=True)
    manifest_lines = []
    for name in ns.classes:
        n, m, particles, k_steps = SIZE_CLASSES[name]
        text = lower_size_class(name, n, m, particles, k_steps)
        path = os.path.join(ns.out_dir, f"pso_epoch_{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} {n} {m} {particles} {k_steps}")
        print(f"wrote {path} ({len(text)} chars)  n={n} m={m} N={particles} K={k_steps}")

    with open(os.path.join(ns.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {ns.out_dir}/manifest.txt ({len(manifest_lines)} classes)")


if __name__ == "__main__":
    main()
