"""L2 JAX model: one IMMSched PSO *epoch* over all particles.

The AOT unit is exactly one epoch of Algorithm 1 — K fused inner steps
(L1 Pallas kernel) for all N particles, with per-particle local-best
tracking — because that is the part of the algorithm with *no*
cross-particle data dependency.  Everything that couples particles
(global best S*, elite consensus S̄, the feasible-mapping set M, the
projection + Ullmann refinement) belongs to the global controller, which
lives in the rust coordinator (L3) exactly as the paper puts it in the
lightweight on-chip controller.

The epoch is a pure function:

    (S, V, S_local, f_local, S*, S̄, Mask, Q, G, seed, coefs)
        → (S', V', S_local', f_local', f_last)

* randoms are generated **in-graph** (threefry, folded per step) so the
  host never ships per-step random tensors across the PJRT boundary;
* the K-step loop is a `lax.scan`, keeping the lowered HLO small and
  compile times flat in K;
* S*/S̄ are *frozen inputs* for the epoch — the rust controller updates
  them between epochs from the returned bests (consensus-guided
  exploration, paper §3.4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.pso_step import pso_step


def _epoch(step_fn, k_steps, s, v, s_local, f_local, s_star, s_bar, mask, q, g, seed, coefs):
    """Shared epoch driver, parameterized by the fused-step implementation."""
    key = jax.random.PRNGKey(seed)
    n_particles, n, m = s.shape

    def body(carry, k):
        s, v, s_local, f_local = carry
        sub = jax.random.fold_in(key, k)
        r = jax.random.uniform(sub, (3, n_particles, n, m), dtype=jnp.float32)
        s_new, v_new, f = step_fn(
            s, v, s_local, s_star, s_bar, mask, q, g, r[0], r[1], r[2], coefs
        )
        better = f > f_local
        s_local_new = jnp.where(better[:, None, None], s_new, s_local)
        f_local_new = jnp.where(better, f, f_local)
        return (s_new, v_new, s_local_new, f_local_new), f

    (s, v, s_local, f_local), f_hist = jax.lax.scan(
        body, (s, v, s_local, f_local), jnp.arange(k_steps, dtype=jnp.uint32)
    )
    # f_last: fitness of the *final* positions, used by the controller for
    # elite-consensus weighting; f_hist's last row is exactly that.
    return s, v, s_local, f_local, f_hist[-1]


def pso_epoch(s, v, s_local, f_local, s_star, s_bar, mask, q, g, seed, coefs, *, k_steps):
    """One epoch using the Pallas fused step (the production path)."""
    return _epoch(
        pso_step, k_steps, s, v, s_local, f_local, s_star, s_bar, mask, q, g, seed, coefs
    )


def pso_epoch_reference(
    s, v, s_local, f_local, s_star, s_bar, mask, q, g, seed, coefs, *, k_steps
):
    """Same epoch on the pure-jnp oracle — the test-time twin of pso_epoch."""

    def step_fn(s, v, s_local, s_star, s_bar, mask, q, g, r1, r2, r3, coefs):
        return ref.pso_step(
            s, v, s_local, s_star, s_bar, mask, q, g, r1, r2, r3,
            coefs[0], coefs[1], coefs[2], coefs[3],
        )

    return _epoch(
        step_fn, k_steps, s, v, s_local, f_local, s_star, s_bar, mask, q, g, seed, coefs
    )


def epoch_fn(n, m, num_particles, k_steps, *, reference=False):
    """Build the jit-able epoch closure for a fixed size class.

    Returns ``(fn, example_args)`` where ``example_args`` are
    ShapeDtypeStructs suitable for ``jax.jit(fn).lower(*example_args)``.
    Argument order is the PJRT calling convention the rust runtime uses —
    keep in sync with rust/src/runtime/matcher_exec.rs.
    """
    base = functools.partial(
        pso_epoch_reference if reference else pso_epoch, k_steps=k_steps
    )

    def fn(s, v, s_local, f_local, s_star, s_bar, mask, q, g, seed, coefs):
        return base(s, v, s_local, f_local, s_star, s_bar, mask, q, g, seed, coefs)

    f32 = jnp.float32
    args = (
        jax.ShapeDtypeStruct((num_particles, n, m), f32),  # s
        jax.ShapeDtypeStruct((num_particles, n, m), f32),  # v
        jax.ShapeDtypeStruct((num_particles, n, m), f32),  # s_local
        jax.ShapeDtypeStruct((num_particles,), f32),  # f_local
        jax.ShapeDtypeStruct((n, m), f32),  # s_star
        jax.ShapeDtypeStruct((n, m), f32),  # s_bar
        jax.ShapeDtypeStruct((n, m), f32),  # mask
        jax.ShapeDtypeStruct((n, n), f32),  # q
        jax.ShapeDtypeStruct((m, m), f32),  # g
        jax.ShapeDtypeStruct((), jnp.uint32),  # seed
        jax.ShapeDtypeStruct((4,), f32),  # coefs [w, c1, c2, c3]
    )
    return fn, args


# Size classes lowered by aot.py.  Names + dims must stay in sync with the
# rust artifact registry (rust/src/runtime/artifact.rs) and the Makefile.
# (n, m) are padded powers of two chosen so the "large" class puts m at the
# MXU-native lane width 128.
SIZE_CLASSES = {
    # name: (n, m, num_particles, k_steps)
    "small": (8, 16, 8, 8),
    "medium": (16, 32, 16, 8),
    "large": (32, 64, 16, 8),
    "xlarge": (64, 128, 16, 8),
}
