"""L2 profiling: HLO cost analysis + VMEM/MXU estimates per size class.

The performance deliverable for L1/L2 (DESIGN.md §8): interpret-mode
wallclock is CPU-numpy and NOT a TPU proxy, so the optimization loop
works on *structural* metrics:

  * XLA's HLO cost analysis (flops / bytes accessed / peak memory) of
    the lowered epoch — catches redundant recomputation and fusion
    regressions between edits;
  * the analytic VMEM footprint of one particle-step working set — must
    stay under a TPU core's ~16 MiB;
  * the MXU utilization bound: fitness matmul FLOPs over total FLOPs
    (the fraction of the epoch that can run on the systolic array).

Usage:  cd python && python -m compile.analyze [--classes small ...]
Writes reports/l2_cost_analysis.csv and prints the table.
"""

from __future__ import annotations

import argparse
import os

import jax

from .model import SIZE_CLASSES, epoch_fn


def cost_analysis(n, m, particles, k_steps):
    """Compile the epoch and pull XLA's cost analysis."""
    fn, args = epoch_fn(n, m, particles, k_steps)
    compiled = jax.jit(fn).lower(*args).compile()
    # jax >= 0.4 returns a dict (or list of dicts) of named costs
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca or {}


def vmem_footprint_bytes(n, m):
    """One particle-step working set (DESIGN.md §8), f32."""
    per_particle = 3 * n * m  # S, V, S_local
    shared = 3 * n * m + n * n + m * m  # S*, S̄, Mask, Q, G
    randoms = 3 * n * m
    return 4 * (per_particle + shared + randoms)


def mxu_fraction(n, m, particles, k_steps):
    """FLOPs on the MXU (fitness matmuls) / total epoch FLOPs."""
    matmul = 2 * (n * m * m + n * n * m)  # S·G and (SG)·Sᵀ, 2 flops/MAC
    eltwise = 14 * n * m  # velocity(8) + position/clip(2) + mask(1) + renorm(3)
    total = matmul + eltwise
    return matmul / total, particles * k_steps * total


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--classes", nargs="*", default=list(SIZE_CLASSES))
    parser.add_argument("--out", default="../reports/l2_cost_analysis.csv")
    ns = parser.parse_args()

    rows = []
    for name in ns.classes:
        n, m, particles, k_steps = SIZE_CLASSES[name]
        ca = cost_analysis(n, m, particles, k_steps)
        flops = ca.get("flops", float("nan"))
        bytes_accessed = ca.get("bytes accessed", float("nan"))
        vmem = vmem_footprint_bytes(n, m)
        frac, analytic_flops = mxu_fraction(n, m, particles, k_steps)
        rows.append(
            {
                "class": name,
                "n": n,
                "m": m,
                "particles": particles,
                "k": k_steps,
                "xla_flops": flops,
                "xla_bytes": bytes_accessed,
                "analytic_flops": analytic_flops,
                "vmem_step_bytes": vmem,
                "vmem_frac_of_16MiB": vmem / (16 * 1024 * 1024),
                "mxu_flop_fraction": frac,
            }
        )
        print(
            f"{name:8s} n={n:3d} m={m:3d}  xla_flops={flops:.3e}  "
            f"vmem/step={vmem / 1024:.1f} KiB ({vmem / (16 * 2**20) * 100:.2f}% of 16 MiB)  "
            f"mxu_frac={frac:.3f}"
        )

    os.makedirs(os.path.dirname(ns.out), exist_ok=True)
    with open(ns.out, "w") as f:
        cols = list(rows[0].keys())
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r[c]) for c in cols) + "\n")
    print(f"wrote {ns.out}")


if __name__ == "__main__":
    main()
