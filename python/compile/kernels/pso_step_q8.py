"""L1 Pallas kernel: quantization-aware fused PSO step (u8 / i32 datapath).

Models the paper's §3.4 hardware mapping exactly:

  * the relaxed mapping S lives on the uniform u8 grid (0..255 ↔ 0..1);
  * the two fitness matmuls (S·G, (SG)·Sᵀ) consume integer operands and
    accumulate in i32 — the accelerator's int8 MAC + i32 accumulator;
  * row renormalization is reciprocal-multiply (no divider in the PEs);
  * velocities stay in f32, matching the lightweight global controller
    that runs the scalar part of the algorithm.

The kernel must agree with kernels/ref.py::pso_step_q8 bit-exactly on the
u8 outputs (quantization is deterministic) and to float tolerance on the
fitness; python/tests/test_kernel.py enforces both.

interpret=True for the same reason as pso_step.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ROW_EPS, Q8_SCALE


def _pso_step_q8_kernel(
    s_ref,  # (1, n, m) u8
    v_ref,  # (1, n, m) f32
    s_local_ref,  # (1, n, m) u8
    r1_ref,
    r2_ref,
    r3_ref,  # (1, n, m) f32
    s_star_ref,  # (n, m) u8
    s_bar_ref,  # (n, m) u8
    mask_ref,  # (n, m) f32 (binary)
    q_ref,  # (n, n) i32 (binary)
    g_ref,  # (m, m) i32 (binary)
    coef_ref,  # (4,) f32
    s_out_ref,  # (1, n, m) u8
    v_out_ref,  # (1, n, m) f32
    f_out_ref,  # (1,) f32
):
    inv_scale = 1.0 / Q8_SCALE
    s = s_ref[0].astype(jnp.float32) * inv_scale
    s_local = s_local_ref[0].astype(jnp.float32) * inv_scale
    s_star = s_star_ref[...].astype(jnp.float32) * inv_scale
    s_bar = s_bar_ref[...].astype(jnp.float32) * inv_scale
    v = v_ref[0]
    r1, r2, r3 = r1_ref[0], r2_ref[0], r3_ref[0]
    mask = mask_ref[...]
    w, c1, c2, c3 = coef_ref[0], coef_ref[1], coef_ref[2], coef_ref[3]

    # Controller-side (f32) part: velocity + position + mask + renorm.
    v_new = (
        w * v
        + c1 * r1 * (s_local - s)
        + c2 * r2 * (s_star - s)
        + c3 * r3 * (s_bar - s)
    )
    s_new = jnp.clip(s + v_new, 0.0, 1.0) * mask
    row_sum = jnp.sum(s_new, axis=-1, keepdims=True)
    recip = jnp.where(row_sum > ROW_EPS, 1.0 / (row_sum + ROW_EPS), 0.0)
    s_new = s_new * recip

    # Re-quantize onto the u8 grid the MAC array consumes.
    s_q = jnp.clip(jnp.round(s_new * Q8_SCALE), 0.0, 255.0).astype(jnp.uint8)

    # MAC-array-side (integer) part: S G S^T with i32 accumulation.
    s_i = s_q.astype(jnp.int32)
    g_i = g_ref[...]
    q_i = q_ref[...]
    sg = jnp.dot(s_i, g_i, preferred_element_type=jnp.int32)  # (n, m) i32
    sgst = jnp.dot(sg, s_i.T, preferred_element_type=jnp.int32)  # (n, n) i32
    err = q_i.astype(jnp.float32) - sgst.astype(jnp.float32) * (
        inv_scale * inv_scale
    )
    fit = -jnp.sum(err * err)

    s_out_ref[0] = s_q
    v_out_ref[0] = v_new
    f_out_ref[0] = fit


def pso_step_q8(s_q, v, s_local_q, s_star_q, s_bar_q, mask, q, g, r1, r2, r3, coefs):
    """Quantized fused PSO step over all particles.

    Args:
      s_q, s_local_q: (N, n, m) u8.   v, r1, r2, r3: (N, n, m) f32.
      s_star_q, s_bar_q: (n, m) u8.   mask: (n, m) f32 binary.
      q: (n, n) i32 binary.  g: (m, m) i32 binary.  coefs: (4,) f32.

    Returns:
      (s_q', v', f') with dtypes (u8, f32, f32).
    """
    n_particles, n, m = s_q.shape
    per_particle = pl.BlockSpec((1, n, m), lambda p: (p, 0, 0))
    shared_nm = pl.BlockSpec((n, m), lambda p: (0, 0))
    shared_nn = pl.BlockSpec((n, n), lambda p: (0, 0))
    shared_mm = pl.BlockSpec((m, m), lambda p: (0, 0))
    shared_c = pl.BlockSpec((4,), lambda p: (0,))

    return pl.pallas_call(
        _pso_step_q8_kernel,
        grid=(n_particles,),
        in_specs=[
            per_particle,  # s_q
            per_particle,  # v
            per_particle,  # s_local_q
            per_particle,  # r1
            per_particle,  # r2
            per_particle,  # r3
            shared_nm,  # s_star_q
            shared_nm,  # s_bar_q
            shared_nm,  # mask
            shared_nn,  # q
            shared_mm,  # g
            shared_c,  # coefs
        ],
        out_specs=[
            per_particle,
            per_particle,
            pl.BlockSpec((1,), lambda p: (p,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_particles, n, m), jnp.uint8),
            jax.ShapeDtypeStruct((n_particles, n, m), jnp.float32),
            jax.ShapeDtypeStruct((n_particles,), jnp.float32),
        ],
        interpret=True,
    )(s_q, v, s_local_q, r1, r2, r3, s_star_q, s_bar_q, mask, q, g, coefs)
