"""Pure-jnp reference oracle for the IMMSched PSO-step kernels.

This file is the *specification*: the Pallas kernels in pso_step.py /
pso_step_q8.py must agree with these functions to numerical tolerance
(pytest + hypothesis enforce it).  Everything here is plain jax.numpy —
no Pallas, no custom calls — so it runs anywhere and is trivially
auditable against Algorithm 1 of the paper.

Shapes
------
  S, V, S_local, r1, r2, r3 : (N, n, m)   particle-batched relaxed mappings
  S_star, S_bar, mask       : (n, m)      global best / consensus / mask
  Q                         : (n, n)      query adjacency (0/1 floats)
  G                         : (m, m)      target adjacency (0/1 floats)

Conventions
-----------
* A row of S is the probability distribution of one query vertex over
  target vertices; rows are renormalized after every position update
  (paper §3.2: "each row of S sums to 1").
* Rows whose mask is all-zero stay all-zero (the query vertex has no
  compatible target vertex; the mapping is infeasible and the fitness
  will reflect it).
* Row normalization uses multiply-by-reciprocal, mirroring the paper's
  divider-free hardware datapath (§3.4).
"""

from __future__ import annotations

import jax.numpy as jnp

# Small epsilon used by the reciprocal row normalization.  Kept as a
# module constant so the Pallas kernels and the oracle share one value.
ROW_EPS = 1e-9


def velocity(v, s, s_local, s_star, s_bar, r1, r2, r3, w, c1, c2, c3):
    """PSO velocity update with the consensus term (Algorithm 1, line 8).

    v' = w*v + c1*r1*(S_local - S) + c2*r2*(S* - S) + c3*r3*(S_bar - S)

    ``s_star`` / ``s_bar`` broadcast over the particle axis.
    """
    return (
        w * v
        + c1 * r1 * (s_local - s)
        + c2 * r2 * (s_star[None, :, :] - s)
        + c3 * r3 * (s_bar[None, :, :] - s)
    )


def position(s, v):
    """Position update, clipped to the relaxed domain [0, 1] (line 9)."""
    return jnp.clip(s + v, 0.0, 1.0)


def apply_mask(s, mask):
    """Zero out incompatible (tile, PE) pairs (line 10)."""
    return s * mask[None, :, :]


def row_normalize(s):
    """Renormalize every row to sum 1 via reciprocal multiply (line 11).

    All-zero rows remain all-zero rather than producing NaNs.
    """
    row_sum = jnp.sum(s, axis=-1, keepdims=True)
    recip = jnp.where(row_sum > ROW_EPS, 1.0 / (row_sum + ROW_EPS), 0.0)
    return s * recip


def fitness(s, q, g):
    """Edge-preserving fitness  f = -|| Q - S G S^T ||_F^2  (§3.3).

    Higher is better; 0 is a perfect relaxed embedding.
    Batched over the leading particle axis of ``s``.
    """
    sg = jnp.einsum("pnm,mk->pnk", s, g)  # (N, n, m)
    sgst = jnp.einsum("pnk,pmk->pnm", sg, s)  # (N, n, n)
    err = q[None, :, :] - sgst
    return -jnp.sum(err * err, axis=(-2, -1))


def pso_step(s, v, s_local, s_star, s_bar, mask, q, g, r1, r2, r3, w, c1, c2, c3):
    """One full fused PSO step — the contract of the Pallas kernel.

    Returns (s', v', f') where f' is the fitness of the *new* position.
    """
    v_new = velocity(v, s, s_local, s_star, s_bar, r1, r2, r3, w, c1, c2, c3)
    s_new = position(s, v_new)
    s_new = apply_mask(s_new, mask)
    s_new = row_normalize(s_new)
    f_new = fitness(s_new, q, g)
    return s_new, v_new, f_new


# ---------------------------------------------------------------------------
# Quantized (u8 / i32) reference — mirrors the paper's §3.4 datapath.
# ---------------------------------------------------------------------------

Q8_SCALE = 255.0  # S is uniformly quantized onto [0, 255] <-> [0.0, 1.0]


def quantize_u8(s):
    """Uniform quantization of a [0,1] relaxed mapping to u8 codes."""
    return jnp.clip(jnp.round(s * Q8_SCALE), 0.0, 255.0).astype(jnp.uint8)


def dequantize_u8(s_q):
    """Inverse of :func:`quantize_u8` (exact on the code grid)."""
    return s_q.astype(jnp.float32) / Q8_SCALE


def fitness_q8(s_q, q, g):
    """Fitness evaluated on the int8 MAC datapath model.

    The accelerator computes S G S^T with u8 inputs and i32 accumulation;
    the error against the binary Q is formed after rescaling by 1/255 per
    S factor.  We model this exactly: integer matmuls in i32, one final
    float rescale.  ``q``/``g`` are 0/1 and stay integral.
    """
    s_i = s_q.astype(jnp.int32)  # (N, n, m)
    g_i = g.astype(jnp.int32)  # (m, m)
    q_i = q.astype(jnp.int32)  # (n, n)
    sg = jnp.einsum("pnm,mk->pnk", s_i, g_i)  # i32, exact
    sgst = jnp.einsum("pnk,pmk->pnm", sg, s_i)  # i32, exact (fits: 255^2*m)
    err = q_i[None].astype(jnp.float32) - sgst.astype(jnp.float32) / (
        Q8_SCALE * Q8_SCALE
    )
    return -jnp.sum(err * err, axis=(-2, -1))


def pso_step_q8(
    s_q, v, s_local_q, s_star_q, s_bar_q, mask, q, g, r1, r2, r3, w, c1, c2, c3
):
    """Quantized fused step: positions live on the u8 grid, velocity in f32.

    Matches the hardware model where the MAC array consumes u8 S while the
    lightweight controller keeps velocities in a wider format.  Returns
    (s_q', v', f') with f' computed by :func:`fitness_q8`.
    """
    s = dequantize_u8(s_q)
    s_local = dequantize_u8(s_local_q)
    s_star = dequantize_u8(s_star_q)
    s_bar = dequantize_u8(s_bar_q)
    v_new = velocity(v, s, s_local, s_star, s_bar, r1, r2, r3, w, c1, c2, c3)
    s_new = row_normalize(apply_mask(position(s, v_new), mask))
    s_new_q = quantize_u8(s_new)
    f_new = fitness_q8(s_new_q, q, g)
    return s_new_q, v_new, f_new
