"""L1 Pallas kernel: fused PSO step for parallel subgraph matching.

One grid step = one particle = one accelerator "engine" (paper §3.3: the
multi-particle optimizer maps particles onto distinct engines).  Each grid
step pulls its particle's working set — S, V, S_local plus the shared
S*, S̄, Mask, Q, G and the per-step randoms — into VMEM via BlockSpec,
then fuses the whole Algorithm-1 inner body:

    velocity  → position(clip) → mask ⊙ → row-renorm (reciprocal-mult)
    → edge-preserving fitness  −‖Q − S G Sᵀ‖²

into a single kernel so nothing round-trips to HBM between sub-steps.

TPU adaptation notes (DESIGN.md §3):
  * the particle axis is the Pallas *grid*, the analogue of the paper's
    engine-parallel dispatch;
  * both matmuls (S·G and (SG)·Sᵀ) hit the MXU with m as the lane
    dimension — for the "large" size class m = 128, MXU-native;
  * row normalization is reciprocal-multiply, matching the paper's
    divider-free PE modification (§3.4).

interpret=True always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated against kernels/ref.py and the
real-TPU performance story is estimated analytically (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ROW_EPS


def _pso_step_kernel(
    # per-particle blocks (1, n, m)
    s_ref,
    v_ref,
    s_local_ref,
    r1_ref,
    r2_ref,
    r3_ref,
    # shared blocks
    s_star_ref,  # (n, m)
    s_bar_ref,  # (n, m)
    mask_ref,  # (n, m)
    q_ref,  # (n, n)
    g_ref,  # (m, m)
    coef_ref,  # (4,) = [w, c1, c2, c3]
    # outputs
    s_out_ref,  # (1, n, m)
    v_out_ref,  # (1, n, m)
    f_out_ref,  # (1,)
):
    """Fused Algorithm-1 inner body for a single particle."""
    s = s_ref[0]
    v = v_ref[0]
    s_local = s_local_ref[0]
    r1, r2, r3 = r1_ref[0], r2_ref[0], r3_ref[0]
    s_star = s_star_ref[...]
    s_bar = s_bar_ref[...]
    mask = mask_ref[...]
    q = q_ref[...]
    g = g_ref[...]
    w = coef_ref[0]
    c1 = coef_ref[1]
    c2 = coef_ref[2]
    c3 = coef_ref[3]

    # -- velocity (line 8) ---------------------------------------------------
    v_new = (
        w * v
        + c1 * r1 * (s_local - s)
        + c2 * r2 * (s_star - s)
        + c3 * r3 * (s_bar - s)
    )

    # -- position + clip (line 9) --------------------------------------------
    s_new = jnp.clip(s + v_new, 0.0, 1.0)

    # -- compatibility mask (line 10) ----------------------------------------
    s_new = s_new * mask

    # -- row renormalization via reciprocal multiply (line 11, §3.4) ---------
    row_sum = jnp.sum(s_new, axis=-1, keepdims=True)
    recip = jnp.where(row_sum > ROW_EPS, 1.0 / (row_sum + ROW_EPS), 0.0)
    s_new = s_new * recip

    # -- edge-preserving fitness (line 21): both matmuls on the MXU ----------
    sg = jnp.dot(s_new, g, preferred_element_type=jnp.float32)  # (n, m)
    sgst = jnp.dot(sg, s_new.T, preferred_element_type=jnp.float32)  # (n, n)
    err = q - sgst
    fit = -jnp.sum(err * err)

    s_out_ref[0] = s_new
    v_out_ref[0] = v_new
    f_out_ref[0] = fit


@functools.partial(jax.jit, static_argnames=())
def pso_step(s, v, s_local, s_star, s_bar, mask, q, g, r1, r2, r3, coefs):
    """Run the fused PSO step for all particles.

    Args:
      s, v, s_local, r1, r2, r3: (N, n, m) f32.
      s_star, s_bar, mask: (n, m) f32.
      q: (n, n) f32 binary.  g: (m, m) f32 binary.
      coefs: (4,) f32 = [w, c1, c2, c3].

    Returns:
      (s', v', f') with shapes ((N,n,m), (N,n,m), (N,)).
    """
    n_particles, n, m = s.shape
    per_particle = pl.BlockSpec((1, n, m), lambda p: (p, 0, 0))
    shared_nm = pl.BlockSpec((n, m), lambda p: (0, 0))
    shared_nn = pl.BlockSpec((n, n), lambda p: (0, 0))
    shared_mm = pl.BlockSpec((m, m), lambda p: (0, 0))
    shared_c = pl.BlockSpec((4,), lambda p: (0,))

    return pl.pallas_call(
        _pso_step_kernel,
        grid=(n_particles,),
        in_specs=[
            per_particle,  # s
            per_particle,  # v
            per_particle,  # s_local
            per_particle,  # r1
            per_particle,  # r2
            per_particle,  # r3
            shared_nm,  # s_star
            shared_nm,  # s_bar
            shared_nm,  # mask
            shared_nn,  # q
            shared_mm,  # g
            shared_c,  # coefs
        ],
        out_specs=[
            per_particle,
            per_particle,
            pl.BlockSpec((1,), lambda p: (p,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_particles, n, m), jnp.float32),
            jax.ShapeDtypeStruct((n_particles, n, m), jnp.float32),
            jax.ShapeDtypeStruct((n_particles,), jnp.float32),
        ],
        interpret=True,
    )(s, v, s_local, r1, r2, r3, s_star, s_bar, mask, q, g, coefs)
