"""AOT round-trip: lowered HLO text re-executes and matches direct eval.

This is the python half of the interchange contract; the rust half is
rust/src/runtime (tests there execute the same artifacts via PJRT-rs).
"""

from __future__ import annotations

import os

import pytest

# Guard the heavy imports: a jax-less (or hypothesis-less) environment
# must skip this module at collection instead of erroring.
pytest.importorskip("numpy", reason="numpy not installed")
pytest.importorskip("jax", reason="jax not installed - skipping AOT round-trip tests")
pytest.importorskip("hypothesis", reason="hypothesis not installed (tests.test_kernel needs it)")

import numpy as np
from jax._src.lib import xla_client as xc

from compile.aot import lower_size_class, to_hlo_text
from compile.model import SIZE_CLASSES, epoch_fn, pso_epoch
from tests.test_kernel import COEFS
from tests.test_model import epoch_inputs

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_parses_back():
    """Lower 'small' and re-parse the text with XLA's HLO parser.

    The *numeric* round-trip (text -> HloModuleProto -> PJRT compile ->
    execute) is exercised on the rust side (rust/src/runtime tests +
    `immsched selftest`), which is the consumer of this contract; jaxlib
    0.8 no longer accepts HLO protos through its public compile API.
    """
    n, m, p, k = SIZE_CLASSES["small"]
    text = lower_size_class("small", n, m, p, k)
    assert "ENTRY" in text and "HloModule" in text
    mod = xc._xla.hlo_module_from_text(text)
    # Parameter count must match the rust calling convention (11 inputs).
    prog = mod.to_string()
    assert prog.count("parameter(") >= 11


def test_epoch_io_contract():
    """The artifact signature the rust runtime hard-codes: 11 in, 5 out."""
    n, m, p, k = SIZE_CLASSES["small"]
    rng = np.random.default_rng(21)
    s, v, sl, f_local, ss, sb, mask, q, g = epoch_inputs(rng, p, n, m)
    out = pso_epoch(s, v, sl, f_local, ss, sb, mask, q, g, np.uint32(5), COEFS, k_steps=k)
    shapes = [np.asarray(o).shape for o in out]
    assert shapes == [(p, n, m), (p, n, m), (p, n, m), (p,), (p,)]


def test_artifacts_exist_and_match_manifest():
    """make artifacts must have produced one file per size class."""
    manifest = os.path.join(ARTIFACT_DIR, "manifest.txt")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(manifest) as f:
        lines = [l.split() for l in f.read().strip().splitlines()]
    names = {l[0] for l in lines}
    assert names == set(SIZE_CLASSES), f"manifest {names} != {set(SIZE_CLASSES)}"
    for name, n, m, p, k in lines:
        assert (int(n), int(m), int(p), int(k)) == SIZE_CLASSES[name]
        path = os.path.join(ARTIFACT_DIR, f"pso_epoch_{name}.hlo.txt")
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, f"{path} does not look like HLO text"


def test_hlo_text_is_stable():
    """Same size class lowers to identical text (reproducible builds)."""
    n, m, p, k = SIZE_CLASSES["small"]
    a = lower_size_class("small", n, m, p, k)
    b = lower_size_class("small", n, m, p, k)
    assert a == b


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
