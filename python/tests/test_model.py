"""L2 epoch invariants: Pallas epoch == reference epoch, bests monotone."""

from __future__ import annotations

import pytest

# Guard the heavy imports: a jax-less (or hypothesis-less) environment
# must skip this module at collection instead of erroring.
pytest.importorskip("numpy", reason="numpy not installed")
pytest.importorskip("jax", reason="jax not installed - skipping L2 epoch tests")
pytest.importorskip("hypothesis", reason="hypothesis not installed (tests.test_kernel needs it)")

import jax
import numpy as np

from compile.model import SIZE_CLASSES, epoch_fn, pso_epoch, pso_epoch_reference
from tests.test_kernel import COEFS, make_inputs


def epoch_inputs(rng, n_particles, n, m):
    s, v, sl, ss, sb, mask, q, g, _ = make_inputs(rng, n_particles, n, m)
    f_local = np.full((n_particles,), -np.inf, dtype=np.float32)
    return s, v, sl, f_local, ss, sb, mask, q, g


@pytest.mark.parametrize("n_particles,n,m,k", [(4, 8, 16, 4), (8, 6, 10, 6)])
def test_epoch_matches_reference(n_particles, n, m, k):
    rng = np.random.default_rng(3)
    args = epoch_inputs(rng, n_particles, n, m)
    seed = np.uint32(1234)
    got = pso_epoch(*args, seed, COEFS, k_steps=k)
    exp = pso_epoch_reference(*args, seed, COEFS, k_steps=k)
    names = ["s", "v", "s_local", "f_local", "f_last"]
    for g_, e_, name in zip(got, exp, names):
        np.testing.assert_allclose(
            np.asarray(g_), np.asarray(e_), rtol=1e-4, atol=1e-4, err_msg=name
        )


def test_local_best_monotone():
    """f_local never decreases across an epoch (Algorithm 1 lines 12-13)."""
    rng = np.random.default_rng(5)
    args = epoch_inputs(rng, 6, 8, 16)
    f0 = np.full((6,), -1e30, dtype=np.float32)
    args = args[:3] + (f0,) + args[4:]
    out = pso_epoch(*args, np.uint32(7), COEFS, k_steps=8)
    f_local = np.asarray(out[3])
    f_last = np.asarray(out[4])
    assert np.all(f_local >= f_last - 1e-4), "local best must dominate last fitness"


def test_epoch_improves_fitness_on_average():
    """Optimization sanity: epochs should (statistically) improve fitness."""
    rng = np.random.default_rng(9)
    s, v, sl, f_local, ss, sb, mask, q, g = epoch_inputs(rng, 8, 8, 16)
    from compile.kernels import ref

    f_init = np.asarray(ref.fitness(s, q, g))
    out = pso_epoch(s, v, sl, f_local, ss, sb, mask, q, g, np.uint32(11), COEFS, k_steps=8)
    f_best = np.asarray(out[3])
    assert f_best.max() >= f_init.max() - 1e-5


def test_epoch_deterministic_given_seed():
    rng = np.random.default_rng(13)
    args = epoch_inputs(rng, 4, 8, 16)
    a = pso_epoch(*args, np.uint32(99), COEFS, k_steps=4)
    b = pso_epoch(*args, np.uint32(99), COEFS, k_steps=4)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    c = pso_epoch(*args, np.uint32(100), COEFS, k_steps=4)
    assert not np.array_equal(np.asarray(a[0]), np.asarray(c[0]))


def test_size_classes_lower():
    """Every registered size class must trace + lower without error."""
    for name, (n, m, p, k) in SIZE_CLASSES.items():
        fn, args = epoch_fn(n, m, p, k)
        lowered = jax.jit(fn).lower(*args)
        assert lowered is not None, name


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
