"""Kernel-vs-reference correctness: the CORE L1 signal.

The Pallas fused step (float and q8) must reproduce the pure-jnp oracle in
kernels/ref.py across swept shapes — hypothesis drives (N, n, m), the
mask/graph densities and the PSO coefficients.
"""

from __future__ import annotations

import pytest

# Guard the heavy imports: a jax-less (or hypothesis-less) environment
# must skip this module at collection instead of erroring.
pytest.importorskip("numpy", reason="numpy not installed")
pytest.importorskip("jax", reason="jax/pallas not installed - skipping L1 kernel tests")
pytest.importorskip("hypothesis", reason="hypothesis not installed - skipping L1 kernel tests")

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.pso_step import pso_step
from compile.kernels.pso_step_q8 import pso_step_q8


def make_inputs(rng, n_particles, n, m, mask_density=0.7, q_density=0.3, g_density=0.5):
    """Random, well-formed kernel inputs (row-stochastic S, binary graphs)."""
    s = rng.random((n_particles, n, m), dtype=np.float32) + 1e-3
    mask = (rng.random((n, m)) < mask_density).astype(np.float32)
    # Guarantee at least one compatible target per query vertex so S has
    # support (the all-zero-row case is tested separately).
    mask[np.arange(n), rng.integers(0, m, size=n)] = 1.0
    s = s * mask[None]
    s /= s.sum(-1, keepdims=True)
    v = (rng.random((n_particles, n, m), dtype=np.float32) - 0.5) * 0.2
    s_local = s.copy()
    s_star = s[0]
    s_bar = s.mean(0)
    q = (rng.random((n, n)) < q_density).astype(np.float32)
    np.fill_diagonal(q, 0.0)
    g = (rng.random((m, m)) < g_density).astype(np.float32)
    np.fill_diagonal(g, 0.0)
    r = rng.random((3, n_particles, n, m), dtype=np.float32)
    return s, v, s_local, s_star, s_bar, mask, q, g, r


COEFS = np.array([0.72, 1.49, 1.49, 0.6], dtype=np.float32)


class TestFloatKernel:
    @settings(max_examples=12, deadline=None)
    @given(
        n_particles=st.integers(1, 6),
        n=st.integers(2, 12),
        m=st.integers(2, 24),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference(self, n_particles, n, m, seed):
        rng = np.random.default_rng(seed)
        s, v, sl, ss, sb, mask, q, g, r = make_inputs(rng, n_particles, n, m)
        got_s, got_v, got_f = pso_step(
            s, v, sl, ss, sb, mask, q, g, r[0], r[1], r[2], COEFS
        )
        exp_s, exp_v, exp_f = ref.pso_step(
            s, v, sl, ss, sb, mask, q, g, r[0], r[1], r[2], *COEFS
        )
        np.testing.assert_allclose(got_v, exp_v, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(got_s, exp_s, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(got_f, exp_f, rtol=1e-4, atol=1e-4)

    def test_rows_stochastic_after_step(self):
        rng = np.random.default_rng(7)
        s, v, sl, ss, sb, mask, q, g, r = make_inputs(rng, 4, 8, 16)
        got_s, _, _ = pso_step(s, v, sl, ss, sb, mask, q, g, r[0], r[1], r[2], COEFS)
        sums = np.asarray(got_s).sum(-1)
        np.testing.assert_allclose(sums, np.ones_like(sums), atol=1e-5)

    def test_mask_respected(self):
        rng = np.random.default_rng(8)
        s, v, sl, ss, sb, mask, q, g, r = make_inputs(rng, 4, 8, 16, mask_density=0.4)
        got_s, _, _ = pso_step(s, v, sl, ss, sb, mask, q, g, r[0], r[1], r[2], COEFS)
        assert np.all(np.asarray(got_s)[:, mask == 0.0] == 0.0)

    def test_all_zero_mask_row_stays_zero(self):
        """A query vertex with no compatible PE must not produce NaNs."""
        rng = np.random.default_rng(9)
        s, v, sl, ss, sb, mask, q, g, r = make_inputs(rng, 2, 6, 12)
        mask[3, :] = 0.0
        got_s, got_v, got_f = pso_step(
            s, v, sl, ss, sb, mask, q, g, r[0], r[1], r[2], COEFS
        )
        assert np.all(np.asarray(got_s)[:, 3, :] == 0.0)
        assert np.all(np.isfinite(np.asarray(got_f)))
        assert np.all(np.isfinite(np.asarray(got_v)))

    def test_perfect_embedding_has_zero_fitness(self):
        """If S is an exact subgraph embedding, -||Q - SGS^T||^2 == 0."""
        n, m = 4, 8
        # Query = path 0->1->2->3 embedded at target vertices 2,3,4,5.
        q = np.zeros((n, n), np.float32)
        for i in range(n - 1):
            q[i, i + 1] = 1.0
        g = np.zeros((m, m), np.float32)
        for j in range(m - 1):
            g[j, j + 1] = 1.0
        # One-hot S mapping i -> i+2; G restricted to that path reproduces Q.
        s = np.zeros((1, n, m), np.float32)
        for i in range(n):
            s[0, i, i + 2] = 1.0
        # Zero velocity/randoms => position unchanged.
        zeros = np.zeros_like(s)
        mask = np.ones((n, m), np.float32)
        coefs = np.array([0.0, 0.0, 0.0, 0.0], np.float32)
        got_s, _, got_f = pso_step(
            s, zeros, s, s[0], s[0], mask, q, g, zeros, zeros, zeros, coefs
        )
        # But SGS^T counts *all* G edges reachable through S's support; with
        # one-hot rows only the embedded edges survive, so fitness is 0 minus
        # the Q edges not covered... here the embedding is exact:
        np.testing.assert_allclose(np.asarray(got_f), [0.0], atol=1e-5)


class TestQuantizedKernel:
    @settings(max_examples=10, deadline=None)
    @given(
        n_particles=st.integers(1, 4),
        n=st.integers(2, 10),
        m=st.integers(2, 20),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference(self, n_particles, n, m, seed):
        rng = np.random.default_rng(seed)
        s, v, sl, ss, sb, mask, q, g, r = make_inputs(rng, n_particles, n, m)
        s_q = np.asarray(ref.quantize_u8(s))
        sl_q = np.asarray(ref.quantize_u8(sl))
        ss_q = np.asarray(ref.quantize_u8(ss))
        sb_q = np.asarray(ref.quantize_u8(sb))
        q_i = q.astype(np.int32)
        g_i = g.astype(np.int32)
        got_s, got_v, got_f = pso_step_q8(
            s_q, v, sl_q, ss_q, sb_q, mask, q_i, g_i, r[0], r[1], r[2], COEFS
        )
        exp_s, exp_v, exp_f = ref.pso_step_q8(
            s_q, v, sl_q, ss_q, sb_q, mask, q, g, r[0], r[1], r[2], *COEFS
        )
        # u8 positions must agree bit-exactly modulo borderline rounding of
        # values exactly at .5 code boundaries — allow 1 code of slack.
        diff = np.abs(np.asarray(got_s).astype(np.int32) - np.asarray(exp_s).astype(np.int32))
        assert diff.max() <= 1, f"u8 codes diverged by {diff.max()}"
        np.testing.assert_allclose(got_v, exp_v, rtol=1e-5, atol=1e-6)
        # Fitness tolerance reflects possible ±1-code position differences.
        np.testing.assert_allclose(got_f, exp_f, rtol=5e-2, atol=5e-2)

    def test_q8_tracks_float_kernel(self):
        """Quantized fitness ≈ float fitness within quantization error."""
        rng = np.random.default_rng(11)
        s, v, sl, ss, sb, mask, q, g, r = make_inputs(rng, 4, 8, 16)
        _, _, f_float = pso_step(s, v, sl, ss, sb, mask, q, g, r[0], r[1], r[2], COEFS)
        s_q = np.asarray(ref.quantize_u8(s))
        got_s, _, f_q8 = pso_step_q8(
            np.asarray(s_q),
            v,
            np.asarray(ref.quantize_u8(sl)),
            np.asarray(ref.quantize_u8(ss)),
            np.asarray(ref.quantize_u8(sb)),
            mask,
            q.astype(np.int32),
            g.astype(np.int32),
            r[0],
            r[1],
            r[2],
            COEFS,
        )
        f_float = np.asarray(f_float)
        f_q8 = np.asarray(f_q8)
        # Relative agreement: quantization error on S is <= 1/255 per entry.
        rel = np.abs(f_q8 - f_float) / (np.abs(f_float) + 1.0)
        assert rel.max() < 0.1, f"q8 fitness drifted: {rel.max():.3f}"

    def test_quantize_roundtrip_on_grid(self):
        codes = np.arange(256, dtype=np.uint8).reshape(1, 16, 16)
        back = ref.quantize_u8(ref.dequantize_u8(codes))
        np.testing.assert_array_equal(np.asarray(back), codes)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
