"""L2 profiling-tool invariants (compile/analyze.py)."""

from __future__ import annotations

import math

import pytest

# compile.analyze imports jax at module scope; without jax the whole
# module must skip at collection, not error.
pytest.importorskip("jax", reason="jax not installed - skipping L2 profiling tests")

from compile.analyze import cost_analysis, mxu_fraction, vmem_footprint_bytes
from compile.model import SIZE_CLASSES


def test_vmem_footprint_formula():
    # 9*n*m + n^2 + m^2 floats, 4 bytes each
    n, m = 8, 16
    assert vmem_footprint_bytes(n, m) == 4 * (9 * n * m + n * n + m * m)


def test_vmem_fits_tpu_core_for_all_classes():
    for name, (n, m, _, _) in SIZE_CLASSES.items():
        vmem = vmem_footprint_bytes(n, m)
        assert vmem < 16 * 2**20 * 0.1, f"{name}: {vmem} bytes won't double-buffer"


def test_mxu_fraction_grows_with_size():
    fracs = []
    for n, m, p, k in SIZE_CLASSES.values():
        frac, total = mxu_fraction(n, m, p, k)
        assert 0.5 < frac < 1.0
        assert total > 0
        fracs.append(frac)
    # matmul share dominates more as m grows
    assert fracs == sorted(fracs)


def test_cost_analysis_reports_flops():
    n, m, p, k = SIZE_CLASSES["small"]
    ca = cost_analysis(n, m, p, k)
    flops = ca.get("flops", float("nan"))
    assert not math.isnan(flops) and flops > 0
    # XLA's count must be within 10x of the analytic step count (same
    # order — it also counts RNG + bookkeeping)
    _, analytic = mxu_fraction(n, m, p, k)
    assert flops > analytic * 0.1
    assert flops < analytic * 100


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
